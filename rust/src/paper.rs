//! Paper-table/figure regeneration (the experiment index of DESIGN.md §4).
//!
//! Every public function returns a `Table` whose rows mirror one table or
//! figure in the paper's evaluation; `all_tables()` is what
//! `alst tables` / `cargo bench --bench bench_tables` emit. Absolute
//! numbers come from the calibrated simulator (DESIGN.md substitutions);
//! the asserted properties are the *shapes*: who wins, by what order of
//! magnitude, where the binding constraint moves.

use crate::config::{preset, ClusterConfig, FeatureFlags, ModelPreset, PlanKind, GIB};
use crate::memory::{max_seqlen_search, Estimator};
use crate::perf::{iteration_time, IterationModel};
use crate::tiling::{plan_logits, plan_mlp};
use crate::util::bench::{fmt_duration_hms, fmt_seqlen, Table};

fn cluster_for(world: usize) -> ClusterConfig {
    if world <= 1 {
        ClusterConfig::h100_single()
    } else {
        ClusterConfig::h100(world.div_ceil(8))
    }
}

/// Flags used for the paper's "baseline" bars, incl. the single-GPU
/// weights-offload special case (§5.5 fn.24).
fn baseline_for(world: usize) -> FeatureFlags {
    let mut f = FeatureFlags::baseline();
    if world == 1 {
        f.weights_offload = true;
    }
    f
}

fn alst_for(world: usize) -> FeatureFlags {
    let mut f = FeatureFlags::alst();
    if world == 1 {
        f.weights_offload = true;
    }
    f
}

fn search_row(model: &ModelPreset, world: usize, flags: FeatureFlags) -> (usize, &'static str, f64, f64) {
    let cluster = cluster_for(world);
    let est = Estimator::new(model, cluster.clone(), flags);
    let out = max_seqlen_search(&est, world);
    let perf = iteration_time(
        &IterationModel { model: model.clone(), cluster, flags, plan: PlanKind::Ulysses },
        out.max_seqlen.max(1_000),
        world,
    );
    (out.max_seqlen, out.binding, perf.iteration_s, perf.tflops_per_gpu)
}

/// Table 1 / Figure 11: single-node (8 GPU) feature-ablation ladder.
pub fn table1_ablations(model: &ModelPreset, world: usize) -> Table {
    let mut t = Table::new(
        &format!("Table 1: feature ablations ({} on {} GPUs)", model.name, world),
        &["features", "max seqlen", "iter time", "TFLOPS/GPU", "bound by"],
    );
    for (name, flags) in FeatureFlags::table1_ladder() {
        let (seq, bound, iter_s, tflops) = search_row(model, world, flags);
        t.row(&[
            name.to_string(),
            fmt_seqlen(seq),
            fmt_duration_hms(std::time::Duration::from_secs_f64(iter_s)),
            format!("{tflops:.1}"),
            bound.to_string(),
        ]);
    }
    t
}

/// Tables 2/3/4 + Figures 1/12: baseline vs ALST at 1/8/32 GPUs.
pub fn tables_2_3_4(model: &ModelPreset) -> Table {
    let mut t = Table::new(
        &format!("Tables 2-4: baseline vs ALST ({})", model.name),
        &["gpus", "setup", "max seqlen", "iter time", "TFLOPS/GPU", "improvement"],
    );
    for world in [1usize, 8, 32] {
        let (b_seq, _, b_iter, b_tf) = search_row(model, world, baseline_for(world));
        let (a_seq, _, a_iter, a_tf) = search_row(model, world, alst_for(world));
        t.row(&[
            world.to_string(),
            "baseline".into(),
            fmt_seqlen(b_seq),
            fmt_duration_hms(std::time::Duration::from_secs_f64(b_iter)),
            format!("{b_tf:.1}"),
            "1x".into(),
        ]);
        t.row(&[
            world.to_string(),
            "ALST".into(),
            fmt_seqlen(a_seq),
            fmt_duration_hms(std::time::Duration::from_secs_f64(a_iter)),
            format!("{a_tf:.1}"),
            format!("{:.0}x", a_seq as f64 / b_seq.max(1) as f64),
        ]);
    }
    t
}

/// Figures 8/9/10: max seqlen vs GPU count for each evaluation model.
pub fn fig_8_9_10(model_name: &str, gpu_range: &[usize]) -> Table {
    let model = preset(model_name).expect("known preset");
    let mut t = Table::new(
        &format!("Figure 8-10: max seqlen scaling ({model_name})"),
        &["gpus", "sp", "max seqlen", "bound by", "seqlen/gpu"],
    );
    for &world in gpu_range {
        let flags = alst_for(world);
        let est = Estimator::new(model, cluster_for(world), flags);
        let sp = est.sp_degree(world);
        let out = max_seqlen_search(&est, world);
        if out.max_seqlen == 0 {
            t.row(&[
                world.to_string(),
                sp.to_string(),
                "OOM".into(),
                out.binding.to_string(),
                "-".into(),
            ]);
            continue;
        }
        t.row(&[
            world.to_string(),
            sp.to_string(),
            fmt_seqlen(out.max_seqlen),
            out.binding.to_string(),
            fmt_seqlen(out.max_seqlen / world),
        ]);
    }
    t
}

/// Figure 2: estimated activation memory vs sequence length (Llama-8B).
pub fn fig2_activation_memory() -> Table {
    let model = preset("llama3-8b").unwrap();
    let est = Estimator::new(model, ClusterConfig::h100(1), FeatureFlags::baseline());
    let mut t = Table::new(
        "Figure 2: Llama-8B activation memory vs seqlen (per GPU, baseline)",
        &["seqlen", "ckpt GiB", "logits GiB", "work GiB", "total GiB"],
    );
    for seq in [32_768usize, 65_536, 131_072, 262_144, 524_288, 1_048_576] {
        let b = est.breakdown(seq, 8);
        let gib = |x: u64| x as f64 / GIB as f64;
        let work = b.acts.attn_work + b.acts.mlp_work + b.acts.resid_work;
        t.row(&[
            fmt_seqlen(seq),
            format!("{:.1}", gib(b.acts.ckpt_device)),
            format!("{:.1}", gib(b.acts.logits_work)),
            format!("{:.1}", gib(work)),
            format!("{:.1}", gib(b.acts.device_peak())),
        ]);
    }
    t
}

/// Figure 3: loss-computation peak memory, untiled vs tiled (16K, Llama-8B
/// vocab). The paper measured 50 -> 36 GiB on the full model; we report
/// the loss-head delta the tiling is responsible for.
pub fn fig3_tiled_loss() -> Table {
    let mut t = Table::new(
        "Figure 3: logits+loss peak memory, untiled vs tiled (fp32)",
        &["seqlen", "untiled GiB", "tiled GiB", "chunks", "saved GiB"],
    );
    for seq in [16_000usize, 32_000, 64_000, 128_000] {
        let plan = plan_logits(seq, 128_256, GIB);
        let gib = |x: u64| x as f64 / GIB as f64;
        t.row(&[
            fmt_seqlen(seq),
            format!("{:.1}", gib(plan.untiled_bytes)),
            format!("{:.1}", gib(plan.tile_bytes)),
            plan.n_tiles.to_string(),
            format!("{:.1}", gib(plan.untiled_bytes - plan.tile_bytes)),
        ]);
    }
    t
}

/// Figure 4: TiledMLP memory on the single-layer 256K x 4096 example.
pub fn fig4_tiled_mlp() -> Table {
    let mut t = Table::new(
        "Figure 4: LlamaMLP fwd+bwd memory, untiled vs TiledMLP (bf16)",
        &["seqlen", "untiled GiB", "tiled GiB", "shards", "saving"],
    );
    for seq in [64_000usize, 128_000, 256_000, 512_000] {
        let plan = plan_mlp(seq, 4096, 14336, 2);
        let gib = |x: u64| x as f64 / GIB as f64;
        t.row(&[
            fmt_seqlen(seq),
            format!("{:.1}", gib(plan.untiled_bytes)),
            format!("{:.2}", gib(plan.tile_bytes)),
            plan.n_tiles.to_string(),
            format!("{:.1}x", plan.saving_factor()),
        ]);
    }
    t
}

/// Figure 7: per-step device-memory timeline with/without ckpt offload —
/// replayed through the allocation tracker event by event (the "hill"
/// vs the flat line of the paper's profiler plots).
pub fn fig7_offload_hill() -> Table {
    let model = preset("llama3-8b").unwrap();
    let mut t = Table::new(
        "Figure 7: device-memory timeline per step (Llama-8B, 8 GPUs, 500K)",
        &["setup", "device peak GiB", "host peak GiB", "timeline (fwd...bwd)"],
    );
    for (label, offload) in [("ckpt on device", false), ("ckpt offloaded", true)] {
        let mut f = FeatureFlags::alst();
        f.ckpt_offload = offload;
        let r = crate::memory::simulate_step(model, 500_000, 8, &f, 1 << 45, 1 << 45)
            .expect("simulate");
        let gib = |x: u64| x as f64 / GIB as f64;
        t.row(&[
            label.to_string(),
            format!("{:.1}", gib(r.device_peak)),
            format!("{:.1}", gib(r.host_peak)),
            crate::memory::sparkline(&r.samples, 40),
        ]);
    }
    t
}

/// Design-choice ablation (DESIGN.md §5): how sensitive are the modeled
/// iteration times to the interconnect assumptions? Sweeps the inter-node
/// fabric and PCIe offload bandwidths around the paper's testbed values
/// (EFA ~200 GB/s, PCIe ~50 GB/s) at the Table-4 operating point.
pub fn comm_sensitivity_table() -> Table {
    let model = preset("llama3-8b").unwrap();
    let mut t = Table::new(
        "Ablation: interconnect sensitivity (Llama-8B, 15M tokens, 32 GPUs)",
        &["inter-node GB/s", "pcie GB/s", "iter time", "a2a s", "offload s", "TFLOPS/GPU"],
    );
    for (inter, pcie) in [
        (100e9, 50e9),
        (200e9, 50e9),   // the paper's testbed
        (400e9, 50e9),
        (200e9, 25e9),
        (200e9, 100e9),
    ] {
        let mut cluster = ClusterConfig::h100(4);
        cluster.inter_bw_bytes_per_s = inter;
        cluster.pcie_bw_bytes_per_s = pcie;
        let r = iteration_time(
            &IterationModel {
                model: model.clone(),
                cluster,
                flags: FeatureFlags::alst(),
                plan: PlanKind::Ulysses,
            },
            15_000_000,
            32,
        );
        t.row(&[
            format!("{:.0}", inter / 1e9),
            format!("{:.0}", pcie / 1e9),
            fmt_duration_hms(std::time::Duration::from_secs_f64(r.iteration_s)),
            format!("{:.1}", r.a2a_s),
            format!("{:.1}", r.offload_s),
            format!("{:.1}", r.tflops_per_gpu),
        ]);
    }
    t
}

/// §7.1 limitations: valid SP degrees per model (bounded by q-head count
/// and divisibility), incl. the paper's hypothetical 9q/3kv example.
pub fn sp_limits_table() -> Table {
    let mut t = Table::new(
        "§7.1: Ulysses SP degree limits per model",
        &["model", "q heads", "kv heads", "valid sp degrees", "max sp"],
    );
    let mut models: Vec<ModelPreset> =
        crate::config::PRESETS.iter().cloned().collect();
    models.push(ModelPreset {
        name: "hypothetical-9q3kv",
        params: 0,
        hidden: 9 * 64,
        n_layers: 1,
        n_q_heads: 9,
        n_kv_heads: 3,
        head_dim: 64,
        ffn: 1,
        vocab: 1,
    });
    for m in &models {
        let valid = m.valid_sp_degrees(64);
        t.row(&[
            m.name.to_string(),
            m.n_q_heads.to_string(),
            m.n_kv_heads.to_string(),
            valid.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(","),
            m.max_sp().to_string(),
        ]);
    }
    t
}

/// Everything `alst tables` emits, keyed by CSV file name.
pub fn all_tables() -> Vec<(&'static str, Table)> {
    let m8 = preset("llama3-8b").unwrap();
    vec![
        ("fig2_activation_memory", fig2_activation_memory()),
        ("fig3_tiled_loss", fig3_tiled_loss()),
        ("fig4_tiled_mlp", fig4_tiled_mlp()),
        ("fig7_offload_hill", fig7_offload_hill()),
        ("table1_ablations", table1_ablations(m8, 8)),
        ("tables_2_3_4_llama8b", tables_2_3_4(m8)),
        (
            "fig8_llama8b_scaling",
            fig_8_9_10("llama3-8b", &[1, 2, 4, 8, 16, 32]),
        ),
        (
            "fig9_llama70b_scaling",
            fig_8_9_10("llama3-70b", &[16, 32, 64]),
        ),
        (
            "fig10_qwen32b_scaling",
            fig_8_9_10("qwen3-32b", &[1, 8, 16, 32, 64]),
        ),
        ("sec7_1_sp_limits", sp_limits_table()),
        ("ablation_comm_sensitivity", comm_sensitivity_table()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_generate() {
        let tables = all_tables();
        assert_eq!(tables.len(), 11);
        for (name, t) in &tables {
            assert!(!t.rows.is_empty(), "{name} has no rows");
        }
    }

    #[test]
    fn sp_limits_match_paper_7_1() {
        let t = sp_limits_table();
        let nine_q = t.rows.iter().find(|r| r[0].contains("9q3kv")).unwrap();
        // "if the model has 9 q_heads, you'd need SP to be 1, 3 or 9"
        assert_eq!(nine_q[3], "1,3,9");
        let l70 = t.rows.iter().find(|r| r[0] == "llama3-70b").unwrap();
        assert_eq!(l70[4], "64"); // "SP=64 is the maximum possible"
    }

    #[test]
    fn tables_2_3_4_show_orders_of_magnitude() {
        let t = tables_2_3_4(preset("llama3-8b").unwrap());
        // row layout: [gpus, setup, seqlen, iter, tflops, improvement]
        let improvements: Vec<f64> = t
            .rows
            .iter()
            .filter(|r| r[1] == "ALST")
            .map(|r| r[5].trim_end_matches('x').parse().unwrap())
            .collect();
        assert_eq!(improvements.len(), 3);
        // paper: 16x / 116x / 469x — require >=8x everywhere and growth
        assert!(improvements.iter().all(|&x| x >= 8.0), "{improvements:?}");
        assert!(improvements[2] > improvements[0], "{improvements:?}");
    }

    #[test]
    fn fig8_scaling_is_monotone_nondecreasing() {
        let t = fig_8_9_10("llama3-8b", &[1, 2, 4, 8, 16, 32]);
        let seqs: Vec<&str> = t.rows.iter().map(|r| r[2].as_str()).collect();
        assert!(!seqs.contains(&"OOM"));
    }
}
