//! Hot-path bench: the Ulysses all-to-all relayout (L3's per-layer cost).
//!
//! Two variants per configuration:
//!   * `fresh-alloc` — each call allocates its output buffers (the
//!     committed-baseline behaviour this PR's arena replaced);
//!   * `pooled`      — outputs checked out of a persistent `ScratchArena`
//!     and recycled after use, so steady-state iterations are
//!     allocation-free (the production step-loop path).
//!
//! Emits the machine-readable perf trajectory to repo-root
//! `BENCH_ulysses.json` (schema in DESIGN.md). The `sp=8 llama 32K`
//! point (seq 32768, 32 q heads, d 128) is the acceptance configuration:
//! `pooled` throughput is the number tracked against `fresh-alloc`.

use alst::collectives::Group;
use alst::coordinator::ulysses::{
    a2a_head_to_seq, a2a_head_to_seq_into, a2a_seq_to_head, a2a_seq_to_head_into,
};
use alst::runtime::{HostTensor, ScratchArena};
use alst::util::bench::{quick, BenchReport};
use alst::util::rng::Rng;

fn shards(rng: &mut Rng, sp: usize, ssh: usize, heads: usize, d: usize) -> Vec<HostTensor> {
    (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, heads, d], rng.normal_vec(ssh * heads * d, 1.0)))
        .collect()
}

fn main() {
    println!("bench_ulysses: all-to-all relayout throughput\n");
    let mut rng = Rng::new(0);
    let mut report = BenchReport::new("ulysses");
    for (sp, seq, heads, d, label) in [
        (2usize, 4096usize, 8usize, 64usize, "sp=2 mha-split"),
        (4, 4096, 8, 64, "sp=4 gqa-split"),
        (8, 4096, 4, 64, "sp=8 kv-replicated"),
        (8, 16384, 32, 128, "sp=8 llama-shaped"),
        (8, 32768, 32, 128, "sp=8 llama 32K (acceptance)"),
    ] {
        let ssh = seq / sp;
        let input = shards(&mut rng, sp, ssh, heads, d);
        let g = Group::new(sp);
        // per-direction volumes come from the byte ledger itself (one
        // probe call each), so the GiB/s denominators stay consistent
        // with CommStats even in the kv-replicated regime, where output
        // and input volumes differ
        let full = a2a_seq_to_head(&g, &input).unwrap();
        let s2h_bytes = g.stats().all_to_all_bytes;
        g.reset_stats();
        let _ = a2a_head_to_seq(&g, &full, heads, false).unwrap();
        let h2s_bytes = g.stats().all_to_all_bytes;
        g.reset_stats();

        // ---- seq->head: fresh-alloc baseline vs pooled ------------------
        let r = quick(&format!("a2a seq->head {label} fresh-alloc"), || {
            let out = a2a_seq_to_head(&g, &input).unwrap();
            std::hint::black_box(&out);
        })
        .with_bytes(s2h_bytes);
        println!("    -> {:.2} GiB/s", r.gib_per_s().unwrap_or(0.0));
        report.push(&r);

        let arena = ScratchArena::new();
        let r = quick(&format!("a2a seq->head {label} pooled"), || {
            let out = a2a_seq_to_head_into(&g, &input, &arena).unwrap();
            std::hint::black_box(&out);
            arena.recycle_all(out);
        })
        .with_bytes(s2h_bytes);
        println!(
            "    -> {:.2} GiB/s (arena hit rate {:.3})",
            r.gib_per_s().unwrap_or(0.0),
            arena.hit_rate()
        );
        report.push(&r);

        // ---- head->seq over the forward output --------------------------
        let r = quick(&format!("a2a head->seq {label} fresh-alloc"), || {
            let out = a2a_head_to_seq(&g, &full, heads, false).unwrap();
            std::hint::black_box(&out);
        })
        .with_bytes(h2s_bytes);
        println!("    -> {:.2} GiB/s", r.gib_per_s().unwrap_or(0.0));
        report.push(&r);

        let arena = ScratchArena::new();
        let r = quick(&format!("a2a head->seq {label} pooled"), || {
            let out = a2a_head_to_seq_into(&g, &full, heads, false, &arena).unwrap();
            std::hint::black_box(&out);
            arena.recycle_all(out);
        })
        .with_bytes(h2s_bytes);
        println!(
            "    -> {:.2} GiB/s (arena hit rate {:.3})",
            r.gib_per_s().unwrap_or(0.0),
            arena.hit_rate()
        );
        report.push(&r);

        // ---- replica-sum backward (the fused accumulate pass) -----------
        if heads < sp {
            let kv: Vec<HostTensor> = (0..sp)
                .map(|_| {
                    HostTensor::f32(vec![seq, 1, d], rng.normal_vec(seq * d, 1.0))
                })
                .collect();
            let arena = ScratchArena::new();
            g.reset_stats();
            let _ = a2a_head_to_seq_into(&g, &kv, heads, true, &arena).unwrap();
            let rs_bytes = g.stats().all_to_all_bytes;
            g.reset_stats();
            let r = quick(&format!("a2a head->seq {label} replica-sum pooled"), || {
                let out = a2a_head_to_seq_into(&g, &kv, heads, true, &arena).unwrap();
                std::hint::black_box(&out);
                arena.recycle_all(out);
            })
            .with_bytes(rs_bytes);
            println!("    -> {:.2} GiB/s", r.gib_per_s().unwrap_or(0.0));
            report.push(&r);
        }
    }
    match report.write_repo_root() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nFAILED to write BENCH_ulysses.json: {e}"),
    }
}
