//! Ring-vs-Ulysses plan bench: per-layer comm volume and measured
//! transfer/compute overlap.
//!
//! Two row families:
//!   * `comm cycle` — the wire cost alone, priced by the byte ledgers:
//!     the ring rotation schedule (`ring_comm_cycle`, fwd + bwd bufs and
//!     the dKV homing hop) against the Ulysses a2a relayout schedule
//!     (`relayout_step_cycle`) at the same geometry. Every row carries
//!     `ring_bytes_per_layer` / `a2a_bytes_per_layer` extras so the
//!     trajectory records WHO wins at each shape, not just how fast the
//!     host memcpy was. The acceptance point is the GQA llama shape
//!     (32K tokens, 32 q / 4 kv heads, d=128, sp=8), where the ring's
//!     `2(sp-1)/sp` KV volume beats the a2a's full activation volume;
//!     the MHA row is kept honest — there the ring loses at sp=8.
//!   * `plan attention` — the full `ParallelPlan` step (forward +
//!     backward) at a compute-heavy small shape, async double-buffered
//!     rotation vs the inline baseline, with `overlap_frac`, `stall_ms`
//!     and `copy_ms` extras MEASURED from `RingStats` (the same worker
//!     join-wait accounting the trainer reports), plus the Ulysses plan
//!     on the identical shape for the cross-plan step row.
//!
//! Emits repo-root `BENCH_ring.json` (schema in DESIGN.md).

use alst::collectives::Group;
use alst::config::PlanKind;
use alst::coordinator::plan::{plan_for, AttnShape, ParallelPlan};
use alst::coordinator::ring::{ring_comm_cycle, RingPlan};
use alst::coordinator::ulysses::relayout_step_cycle;
use alst::runtime::{HostTensor, ScratchArena};
use alst::util::bench::{fast_mode, quick, BenchReport};
use alst::util::rng::Rng;

fn shards(rng: &mut Rng, sp: usize, ssh: usize, heads: usize, d: usize) -> Vec<HostTensor> {
    (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, heads, d], rng.normal_vec(ssh * heads * d, 1.0)))
        .collect()
}

fn main() {
    println!("bench_ring: ring rotation vs a2a relayout, overlap accounting\n");
    let mut rng = Rng::new(0);
    let mut report = BenchReport::new("ring");
    let fast = fast_mode();

    // ---- comm cycles, ledger-priced ------------------------------------
    for (sp, seq, n_q, n_kv, d, label) in [
        (8usize, 32_768usize, 32usize, 4usize, 128usize, "sp=8 llama 32K gqa (acceptance)"),
        (8, 32_768, 32, 32, 128, "sp=8 llama 32K mha (ring loses)"),
        (4, 8_192, 8, 2, 64, "sp=4 gqa"),
    ] {
        let ssh = seq / sp;
        let q = shards(&mut rng, sp, ssh, n_q, d);
        let kv = shards(&mut rng, sp, ssh, n_kv, d);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        // one probe cycle each: per-layer volumes come from the byte
        // ledgers, so the extras stay consistent with CommStats
        ring_comm_cycle(&g, &arena, ssh, n_kv, d, 1).unwrap();
        let ring_bytes = g.stats().send_recv_bytes;
        g.reset_stats();
        relayout_step_cycle(&g, &arena, &q, &kv, 1, n_q, n_kv).unwrap();
        let a2a_bytes = g.stats().all_to_all_bytes;
        g.reset_stats();
        // the ledger must agree with the plan's closed-form pricing
        let shape = AttnShape::new(n_q, n_kv, d);
        assert_eq!(
            ring_bytes,
            RingPlan::new(false).comm_bytes_per_layer(seq, &shape, sp, 4),
            "ring ledger vs closed form at {label}"
        );
        println!(
            "  {label}: ring {:.3} GiB/layer vs a2a {:.3} GiB/layer ({})",
            ring_bytes as f64 / (1u64 << 30) as f64,
            a2a_bytes as f64 / (1u64 << 30) as f64,
            if ring_bytes < a2a_bytes { "ring wins" } else { "a2a wins" },
        );

        let r = quick(&format!("ring comm cycle {label}"), || {
            ring_comm_cycle(&g, &arena, ssh, n_kv, d, 1).unwrap();
        })
        .with_bytes(ring_bytes)
        .with_extra("ring_bytes_per_layer", ring_bytes as f64)
        .with_extra("a2a_bytes_per_layer", a2a_bytes as f64);
        println!("    -> {:.2} GiB/s", r.gib_per_s().unwrap_or(0.0));
        report.push(&r);

        let r = quick(&format!("a2a relayout cycle {label}"), || {
            relayout_step_cycle(&g, &arena, &q, &kv, 1, n_q, n_kv).unwrap();
        })
        .with_bytes(a2a_bytes)
        .with_extra("ring_bytes_per_layer", ring_bytes as f64)
        .with_extra("a2a_bytes_per_layer", a2a_bytes as f64);
        println!("    -> {:.2} GiB/s", r.gib_per_s().unwrap_or(0.0));
        report.push(&r);
    }

    // ---- full plan attention step: overlap measured, not asserted ------
    // Compute-heavy small shape so the fold dominates the block memcpy
    // and the async worker's transfer genuinely hides behind it.
    let (sp, seq, n_q, n_kv, d) = if fast {
        (4usize, 512usize, 4usize, 2usize, 32usize)
    } else {
        (4, 2_048, 4, 2, 32)
    };
    let ssh = seq / sp;
    let shape = AttnShape::new(n_q, n_kv, d);
    let cu = [0i32, seq as i32];
    let qs = shards(&mut rng, sp, ssh, n_q, d);
    let ks = shards(&mut rng, sp, ssh, n_kv, d);
    let vs = shards(&mut rng, sp, ssh, n_kv, d);
    let dos = shards(&mut rng, sp, ssh, n_q, d);
    let lbl = format!("{}K q{n_q}/kv{n_kv} d{d} sp{sp}", seq / 1024);

    for (overlap, mode) in [(true, "async"), (false, "inline")] {
        let plan = RingPlan::new(overlap);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let r = quick(&format!("ring attention fwd+bwd {lbl} {mode}"), || {
            let (o, saved) = plan
                .attention_forward(&g, &arena, &qs, &ks, &vs, &shape, &cu)
                .unwrap();
            let (dq, dk, dv) = plan
                .attention_backward(&g, &arena, &qs, &ks, &vs, &dos, &saved, &shape, &cu)
                .unwrap();
            saved.recycle(&arena);
            for t in [o, dq, dk, dv] {
                arena.recycle_all(t);
            }
        });
        let st = plan.stats();
        // stats are cumulative over warmup + timed iters; the frac is a
        // ratio, and the per-iter ms are scaled by the ledger's own
        // per-iteration wire volume
        let iters = (g.stats().send_recv_bytes as f64
            / plan.comm_bytes_per_layer(seq, &shape, sp, 4) as f64)
            .max(1.0);
        let r = r
            .with_bytes((g.stats().send_recv_bytes as f64 / iters) as u64)
            .with_extra("overlap_frac", st.overlap_frac())
            .with_extra("stall_ms", st.stall_ns as f64 / 1e6 / iters)
            .with_extra("copy_ms", st.copy_ns as f64 / 1e6 / iters);
        println!(
            "    -> overlap_frac {:.3} (stall {:.3} ms / copy {:.3} ms per step)",
            st.overlap_frac(),
            st.stall_ns as f64 / 1e6 / iters,
            st.copy_ns as f64 / 1e6 / iters,
        );
        report.push(&r);
    }

    // same shape through the Ulysses plan: the cross-plan step row
    {
        let plan = plan_for(PlanKind::Ulysses);
        let g = Group::new(sp);
        let arena = ScratchArena::new();
        let r = quick(&format!("ulysses attention fwd+bwd {lbl}"), || {
            let (o, saved) = plan
                .attention_forward(&g, &arena, &qs, &ks, &vs, &shape, &cu)
                .unwrap();
            let (dq, dk, dv) = plan
                .attention_backward(&g, &arena, &qs, &ks, &vs, &dos, &saved, &shape, &cu)
                .unwrap();
            saved.recycle(&arena);
            for t in [o, dq, dk, dv] {
                arena.recycle_all(t);
            }
        })
        .with_extra(
            "a2a_bytes_per_layer",
            plan.comm_bytes_per_layer(seq, &shape, sp, 4) as f64,
        )
        .with_extra(
            "ring_bytes_per_layer",
            RingPlan::new(false).comm_bytes_per_layer(seq, &shape, sp, 4) as f64,
        );
        report.push(&r);
    }

    match report.write_repo_root() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nFAILED to write BENCH_ring.json: {e}"),
    }
}
