//! Training-run metrics: per-step records, loss-curve logging, and the
//! run summaries EXPERIMENTS.md quotes.

use std::io::Write;
use std::path::Path;
use std::time::Duration;

use crate::coordinator::pipeline::StepMetrics;

#[derive(Debug, Default)]
pub struct RunLog {
    pub steps: Vec<StepMetrics>,
}

impl RunLog {
    pub fn push(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    /// Mean loss over the first/last `n` steps (loss-curve trend check).
    pub fn mean_loss_head(&self, n: usize) -> f32 {
        let k = n.min(self.steps.len()).max(1);
        self.steps[..k].iter().map(|s| s.loss).sum::<f32>() / k as f32
    }

    pub fn mean_loss_tail(&self, n: usize) -> f32 {
        let len = self.steps.len();
        let k = n.min(len).max(1);
        self.steps[len - k..].iter().map(|s| s.loss).sum::<f32>() / k as f32
    }

    pub fn mean_step_time(&self) -> Duration {
        if self.steps.is_empty() {
            return Duration::ZERO;
        }
        self.steps.iter().map(|s| s.step_time).sum::<Duration>()
            / self.steps.len() as u32
    }

    pub fn total_tokens(&self) -> usize {
        self.steps.iter().map(|s| s.tokens).sum()
    }

    /// CSV: step,loss,grad_norm,ms,a2a_bytes,gather_bytes,rs_bytes,ckpt_bytes
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "step,loss,grad_norm,step_ms,a2a_bytes,gather_bytes,reduce_scatter_bytes,ckpt_transfer_bytes\n",
        );
        for m in &self.steps {
            s.push_str(&format!(
                "{},{:.6},{:.4},{:.1},{},{},{},{}\n",
                m.step,
                m.loss,
                m.grad_norm,
                m.step_time.as_secs_f64() * 1e3,
                m.a2a_bytes,
                m.gather_bytes,
                m.reduce_scatter_bytes,
                m.ckpt_transfer_bytes,
            ));
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// ASCII loss curve (examples print this; no plotting deps offline).
    pub fn ascii_loss_curve(&self, width: usize, height: usize) -> String {
        if self.steps.len() < 2 {
            return String::from("(not enough steps)");
        }
        let losses: Vec<f32> = self.steps.iter().map(|s| s.loss).collect();
        let (min, max) = losses
            .iter()
            .fold((f32::MAX, f32::MIN), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        let span = (max - min).max(1e-6);
        let mut grid = vec![vec![b' '; width]; height];
        for (i, &l) in losses.iter().enumerate() {
            let x = i * (width - 1) / (losses.len() - 1);
            let y = ((max - l) / span * (height - 1) as f32).round() as usize;
            grid[y.min(height - 1)][x] = b'*';
        }
        let mut out = String::new();
        out.push_str(&format!("loss {max:.3}\n"));
        for row in grid {
            out.push_str("  |");
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!("     {min:.3} .. steps 1-{}\n", losses.len()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: u64, loss: f32) -> StepMetrics {
        StepMetrics {
            step: i,
            loss,
            grad_norm: 1.0,
            tokens: 128,
            step_time: Duration::from_millis(10),
            a2a_bytes: 0,
            gather_bytes: 0,
            reduce_scatter_bytes: 0,
            ckpt_transfer_bytes: 0,
            device_peak_bytes: 0,
        }
    }

    #[test]
    fn trend_helpers() {
        let mut log = RunLog::default();
        for i in 0..10 {
            log.push(step(i, 5.0 - i as f32 * 0.3));
        }
        assert!(log.mean_loss_tail(3) < log.mean_loss_head(3));
        assert_eq!(log.total_tokens(), 1280);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::default();
        log.push(step(1, 2.5));
        let csv = log.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn ascii_curve_renders() {
        let mut log = RunLog::default();
        for i in 0..20 {
            log.push(step(i, (20 - i) as f32));
        }
        let art = log.ascii_loss_curve(40, 8);
        assert!(art.contains('*'));
    }
}
