//! Offline stub of the `xla` (xla-rs) API surface used by the alst crate.
//!
//! The host-side data types — `Literal`, `PjRtBuffer`, element types —
//! are implemented for real, so everything that moves tensors around
//! (uploads, literal round-trips, shape accounting) behaves exactly like
//! the real crate. What is NOT here is a PJRT runtime: `compile()` (and
//! therefore any `execute_b`) returns a descriptive error. The alst
//! integration tests, benches, and examples all gate on the presence of
//! `artifacts/` and skip gracefully, so the tier-1 suite passes offline;
//! swapping this path dependency for the real `xla-rs` crate re-enables
//! end-to-end PJRT execution.
//!
//! Swap caveat: this stub's buffer/client types are plain host data and
//! therefore `Send + Sync`, which the coordinator's scoped-thread rank
//! executor (`pipeline::run_ranks` behind `TrainerOptions::parallel_ranks`)
//! relies on. The real xla-rs wraps C++ pointers; if its types are not
//! `Sync`, the parallel rank path will not compile against it — serialize
//! the rank loops (drop the scoped-thread branch of `run_ranks`) or wrap
//! the buffers before swapping.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types the stub can hold. Sealed in spirit: f32 and i32 are the
/// only dtypes the alst pipeline moves (see `runtime::tensor::Dtype`).
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap_ref(data: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }

    fn unwrap_ref(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }

    fn unwrap_ref(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Dense host literal (array or tuple), dims in row-major order.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    fn numel(&self) -> i64 {
        self.dims.iter().product()
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let new: i64 = dims.iter().product();
        if new != self.numel() {
            return err(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            ));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => return err("array_shape on a tuple literal"),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::unwrap_ref(&self.data) {
            Some(v) => Ok(v.to_vec()),
            None => err(format!(
                "to_vec: literal is not {:?}",
                T::element_type()
            )),
        }
    }

    /// Split a tuple literal into its parts (the parts replace `self`).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, Data::Tuple(Vec::new())) {
            Data::Tuple(parts) => Ok(parts),
            other => {
                self.data = other;
                err("decompose_tuple on a non-tuple literal")
            }
        }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], data: Data::Tuple(parts) }
    }
}

/// Parsed HLO-text artifact. The stub only retains the text; a real PJRT
/// backend is required to lower and execute it.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) if !text.trim().is_empty() => Ok(HloModuleProto { text }),
            Ok(_) => err(format!("empty HLO text file {}", path.display())),
            Err(e) => err(format!("reading {}: {e}", path.display())),
        }
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer. In the stub a buffer is a host literal; uploads and
/// downloads are exact, execution is not available.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err("PJRT execution unavailable in the vendored xla stub")
    }
}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (vendored xla shim; PJRT execution unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(
            "PJRT backend unavailable: this build links the vendored xla \
             stub. Swap rust/vendor/xla for the real xla-rs crate to \
             compile and execute HLO artifacts",
        )
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return err(format!(
                "buffer_from_host_buffer: {} elements but dims {:?}",
                data.len(),
                dims
            ));
        }
        let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { lit: Literal::vec1(data).reshape(&dims64)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn execution_is_unavailable_but_buffers_work() {
        let c = PjRtClient::cpu().unwrap();
        let b = c
            .buffer_from_host_buffer(&[1.0f32, 2.0], &[2], None)
            .unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        let comp = XlaComputation::from_proto(&HloModuleProto { text: "hlo".into() });
        assert!(c.compile(&comp).is_err());
    }
}
