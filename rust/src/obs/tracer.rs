//! The span recorder. One `Tracer` per run, shared as `Arc<Tracer>` by the
//! engine, collectives group, memory tracker, tape, and tile drivers.
//!
//! Disabled-mode contract: a span site costs one branch and constructs a
//! stack-only inert guard — no heap allocation, no clock read, no lock.
//! The `String` for a span's name is allocated only when the span is
//! actually recorded (guard drop on an enabled tracer).
//!
//! Concurrency: recording is lock-sharded — span ids come from one atomic
//! counter and each span lands in `shards[id % N]`, so scoped rank threads
//! rarely contend on the same mutex. Rank attribution rides a thread-local
//! set by `run_ranks` around every rank closure (serial and threaded), the
//! same pattern that makes the `CommStats` ledger interleaving-proof: what
//! is recorded per span is order-independent, so the threaded and serial
//! schedules produce the same span multiset.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::collectives::faults::lock_clean;

/// Span taxonomy. `Step` and `Tile` are *containers*: they enclose leaf
/// spans (a tile sweep contains the per-tile exec spans) and are excluded
/// from per-step attribution sums so time is not double-counted.
/// `CopyD2H`/`CopyH2D` are the offload engine's copy-stream lanes: their
/// spans run on worker threads *concurrently* with compute, so they are
/// excluded from the leaf sums too — what the critical path pays for a
/// copy is the `Stall` span recorded where the step actually blocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Category {
    Step,
    Exec,
    Marshal,
    Relayout,
    Collective,
    Offload,
    Optimizer,
    Tile,
    CopyD2H,
    CopyH2D,
    Stall,
    /// Ring attention plan work: per-hop block-kernel compute on the
    /// rotating KV schedule. Transfers themselves appear on the
    /// `Collective` lane (`send_recv`); the time the ring critical path
    /// spends waiting on a transfer is a `Stall` span.
    Ring,
    /// Resilience events: retry backoffs after transient/corrupt faults,
    /// snapshot saves on the resilient-loop cadence, and snapshot
    /// restores after a lost rank. A leaf: recovery time is real
    /// critical-path time the attribution report must show.
    Fault,
}

impl Category {
    pub const ALL: [Category; 13] = [
        Category::Step,
        Category::Exec,
        Category::Marshal,
        Category::Relayout,
        Category::Collective,
        Category::Offload,
        Category::Optimizer,
        Category::Tile,
        Category::CopyD2H,
        Category::CopyH2D,
        Category::Stall,
        Category::Ring,
        Category::Fault,
    ];

    /// Leaf categories enter the attribution sums; containers and the
    /// overlapped copy-stream lanes do not.
    pub const LEAVES: [Category; 9] = [
        Category::Exec,
        Category::Marshal,
        Category::Relayout,
        Category::Collective,
        Category::Offload,
        Category::Optimizer,
        Category::Stall,
        Category::Ring,
        Category::Fault,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Category::Step => "step",
            Category::Exec => "exec",
            Category::Marshal => "marshal",
            Category::Relayout => "relayout",
            Category::Collective => "collective",
            Category::Offload => "offload",
            Category::Optimizer => "optimizer",
            Category::Tile => "tile",
            Category::CopyD2H => "copy_d2h",
            Category::CopyH2D => "copy_h2d",
            Category::Stall => "stall",
            Category::Ring => "ring",
            Category::Fault => "fault",
        }
    }

    /// Stable Chrome-trace thread id (tid=subsystem lane).
    pub fn tid(self) -> u64 {
        match self {
            Category::Step => 0,
            Category::Exec => 1,
            Category::Marshal => 2,
            Category::Relayout => 3,
            Category::Collective => 4,
            Category::Offload => 5,
            Category::Optimizer => 6,
            Category::Tile => 7,
            Category::CopyD2H => 8,
            Category::CopyH2D => 9,
            Category::Stall => 10,
            Category::Ring => 11,
            Category::Fault => 12,
        }
    }

    pub fn is_leaf(self) -> bool {
        !matches!(
            self,
            Category::Step | Category::Tile | Category::CopyD2H | Category::CopyH2D
        )
    }

    /// True for the offload engine's single-stream copy lanes; within one
    /// stream copies serialize, so trace validation rejects nested or
    /// overlapping spans in these lanes.
    pub fn is_copy_stream(self) -> bool {
        matches!(self, Category::CopyD2H | Category::CopyH2D)
    }
}

/// One recorded span. Timestamps are nanoseconds since the tracer's epoch.
#[derive(Debug, Clone)]
pub struct Span {
    pub id: u64,
    pub name: String,
    pub cat: Category,
    /// Simulated rank, from `set_rank` or the `run_ranks` thread-local;
    /// `None` for coordinator-side work (uploads, optimizer bookkeeping).
    pub rank: Option<usize>,
    pub step: Option<u64>,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Bytes moved (ledger parity with `CommStats` / `EngineStats`).
    pub bytes: u64,
    pub arena_hits: u64,
    pub arena_misses: u64,
    /// Net tracked device bytes allocated minus freed while the span was
    /// open on its thread (see [`note_mem`]).
    pub mem_delta: i64,
}

impl Span {
    pub fn end_ns(&self) -> u64 {
        self.start_ns + self.dur_ns
    }

    pub fn dur(&self) -> Duration {
        Duration::from_nanos(self.dur_ns)
    }
}

/// One `MemoryTracker` alloc/free event, correlated to the innermost open
/// span on the recording thread so a memory peak can name its cause.
#[derive(Debug, Clone)]
pub struct MemEvent {
    pub ts_ns: u64,
    pub span_id: Option<u64>,
    pub tag: String,
    /// Signed byte delta: positive for alloc, negative for free.
    pub delta: i64,
    /// Tracked bytes in use immediately after the event.
    pub current: u64,
}

const SHARDS: usize = 8;

#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    epoch: Instant,
    next_id: AtomicU64,
    /// Empty when disabled (a disabled tracer allocates nothing).
    shards: Vec<Mutex<Vec<Span>>>,
}

impl Tracer {
    pub fn new(enabled: bool) -> Tracer {
        let shards = if enabled {
            (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect()
        } else {
            Vec::new()
        };
        Tracer { enabled, epoch: Instant::now(), next_id: AtomicU64::new(1), shards }
    }

    /// The process-wide disabled tracer: the default handle installed into
    /// `Engine` / `Group` / drivers so every span site stays a single
    /// branch when tracing is off, with no per-object allocation.
    pub fn off() -> Arc<Tracer> {
        static OFF: OnceLock<Arc<Tracer>> = OnceLock::new();
        OFF.get_or_init(|| Arc::new(Tracer::new(false))).clone()
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Nanoseconds since this tracer's epoch (the run start).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Open a span. The guard records on drop; the disabled path returns
    /// an inert guard without touching the clock or the heap.
    pub fn span<'t>(&'t self, cat: Category, name: &'t str) -> SpanGuard<'t> {
        if !self.enabled {
            return SpanGuard {
                tracer: None,
                id: 0,
                name,
                cat,
                start_ns: 0,
                start: None,
                dur: None,
                rank: None,
                step: None,
                bytes: 0,
                arena_hits: 0,
                arena_misses: 0,
                mem0: 0,
            };
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let start_ns = self.now_ns();
        push_span_stack(id);
        SpanGuard {
            tracer: Some(self),
            id,
            name,
            cat,
            start_ns,
            start: Some(Instant::now()),
            dur: None,
            rank: None,
            step: None,
            bytes: 0,
            arena_hits: 0,
            arena_misses: 0,
            mem0: mem_counter(),
        }
    }

    fn push(&self, span: Span) {
        let shard = (span.id as usize) % self.shards.len();
        lock_clean(&self.shards[shard]).push(span);
    }

    /// Remove and return all recorded spans, sorted by (start, id).
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.append(&mut lock_clean(s));
        }
        out.sort_by_key(|s| (s.start_ns, s.id));
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_clean(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// RAII span handle. Attributes default to empty; set what applies before
/// the guard drops. `set_dur` overrides the measured elapsed time with an
/// externally timed duration so span sums can reconcile *exactly* with a
/// ledger that accumulated the same `Duration` (e.g. `EngineStats`).
pub struct SpanGuard<'t> {
    tracer: Option<&'t Tracer>,
    id: u64,
    name: &'t str,
    cat: Category,
    start_ns: u64,
    start: Option<Instant>,
    dur: Option<Duration>,
    rank: Option<usize>,
    step: Option<u64>,
    bytes: u64,
    arena_hits: u64,
    arena_misses: u64,
    mem0: i64,
}

impl SpanGuard<'_> {
    /// True when the span will actually be recorded.
    #[inline]
    pub fn active(&self) -> bool {
        self.tracer.is_some()
    }

    /// Span id (0 for an inert guard).
    #[inline]
    pub fn id(&self) -> u64 {
        self.id
    }

    #[inline]
    pub fn set_bytes(&mut self, bytes: u64) {
        self.bytes = bytes;
    }

    #[inline]
    pub fn add_bytes(&mut self, bytes: u64) {
        self.bytes += bytes;
    }

    #[inline]
    pub fn set_rank(&mut self, rank: usize) {
        self.rank = Some(rank);
    }

    #[inline]
    pub fn set_step(&mut self, step: u64) {
        self.step = Some(step);
    }

    #[inline]
    pub fn set_dur(&mut self, dur: Duration) {
        self.dur = Some(dur);
    }

    #[inline]
    pub fn set_arena_delta(&mut self, hits: u64, misses: u64) {
        self.arena_hits = hits;
        self.arena_misses = misses;
    }

    /// Drop without recording. A *failed* collective attempt must not
    /// emit a `Collective` span: span multiset == ledger increments is a
    /// pinned invariant, and failed attempts ledger nothing. The retry
    /// itself is recorded separately on the `Fault` lane.
    pub fn cancel(&mut self) {
        if self.tracer.is_some() {
            pop_span_stack(self.id);
        }
        self.tracer = None;
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let Some(t) = self.tracer else { return };
        pop_span_stack(self.id);
        let dur = self
            .dur
            .unwrap_or_else(|| self.start.map(|s| s.elapsed()).unwrap_or(Duration::ZERO));
        t.push(Span {
            id: self.id,
            name: self.name.to_string(),
            cat: self.cat,
            rank: self.rank.or_else(current_rank),
            step: self.step,
            start_ns: self.start_ns,
            dur_ns: dur.as_nanos() as u64,
            bytes: self.bytes,
            arena_hits: self.arena_hits,
            arena_misses: self.arena_misses,
            mem_delta: mem_counter() - self.mem0,
        });
    }
}

thread_local! {
    static CURRENT_RANK: Cell<Option<usize>> = const { Cell::new(None) };
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    static MEM_COUNTER: Cell<i64> = const { Cell::new(0) };
}

/// The rank tag for spans recorded on this thread, if any.
pub fn current_rank() -> Option<usize> {
    CURRENT_RANK.with(|c| c.get())
}

/// Install this thread's rank tag; returns the previous value so callers
/// can restore it. `run_ranks` brackets every rank closure with this (in
/// both the serial and the scoped-thread path).
pub fn set_current_rank(rank: Option<usize>) -> Option<usize> {
    CURRENT_RANK.with(|c| c.replace(rank))
}

/// RAII rank tag for serial per-rank loops on the coordinator thread.
pub struct RankScope {
    prev: Option<usize>,
}

pub fn rank_scope(rank: usize) -> RankScope {
    RankScope { prev: set_current_rank(Some(rank)) }
}

impl Drop for RankScope {
    fn drop(&mut self) {
        set_current_rank(self.prev);
    }
}

/// Innermost live span on this thread; memory events attach to it.
pub fn current_span() -> Option<u64> {
    SPAN_STACK.with(|s| s.borrow().last().copied())
}

fn push_span_stack(id: u64) {
    SPAN_STACK.with(|s| s.borrow_mut().push(id));
}

fn pop_span_stack(id: u64) {
    SPAN_STACK.with(|s| {
        let mut st = s.borrow_mut();
        // Guards drop LIFO in practice; tolerate out-of-order drops.
        if let Some(pos) = st.iter().rposition(|&x| x == id) {
            st.remove(pos);
        }
    });
}

/// Accumulate a tracked device-byte delta on this thread; open spans
/// snapshot the counter at open and close to derive their `mem_delta`.
/// Called by `MemoryTracker` only while an enabled tracer is attached.
pub fn note_mem(delta: i64) {
    MEM_COUNTER.with(|c| c.set(c.get() + delta));
}

fn mem_counter() -> i64 {
    MEM_COUNTER.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing_and_allocates_nothing() {
        let t = Tracer::new(false);
        {
            let mut g = t.span(Category::Exec, "noop");
            g.set_bytes(123);
            assert!(!g.active());
            assert_eq!(g.id(), 0);
        }
        assert!(t.is_empty());
        assert!(t.drain().is_empty());
        // A disabled tracer has no shard storage at all.
        assert_eq!(t.shards.len(), 0);
    }

    #[test]
    fn enabled_span_records_attributes() {
        let t = Tracer::new(true);
        {
            let mut g = t.span(Category::Collective, "all_gather");
            g.set_bytes(4096);
            g.set_rank(3);
            g.set_arena_delta(2, 1);
        }
        let spans = t.drain();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.name, "all_gather");
        assert_eq!(s.cat, Category::Collective);
        assert_eq!(s.bytes, 4096);
        assert_eq!(s.rank, Some(3));
        assert_eq!((s.arena_hits, s.arena_misses), (2, 1));
        assert!(t.is_empty(), "drain removes spans");
    }

    #[test]
    fn set_dur_overrides_measured_elapsed() {
        let t = Tracer::new(true);
        {
            let mut g = t.span(Category::Exec, "stage");
            std::thread::sleep(Duration::from_millis(2));
            g.set_dur(Duration::from_nanos(777));
        }
        assert_eq!(t.drain()[0].dur_ns, 777);
    }

    #[test]
    fn rank_comes_from_thread_local_when_unset() {
        let t = Tracer::new(true);
        {
            let _scope = rank_scope(5);
            let _g = t.span(Category::Relayout, "a2a");
        }
        {
            let _g = t.span(Category::Marshal, "upload");
        }
        let spans = t.drain();
        let a2a = spans.iter().find(|s| s.name == "a2a").unwrap();
        let up = spans.iter().find(|s| s.name == "upload").unwrap();
        assert_eq!(a2a.rank, Some(5));
        assert_eq!(up.rank, None);
        assert_eq!(current_rank(), None, "rank scope restored");
    }

    #[test]
    fn span_stack_tracks_nesting() {
        let t = Tracer::new(true);
        assert_eq!(current_span(), None);
        {
            let outer = t.span(Category::Step, "step");
            assert_eq!(current_span(), Some(outer.id()));
            {
                let inner = t.span(Category::Exec, "stage");
                assert_eq!(current_span(), Some(inner.id()));
            }
            assert_eq!(current_span(), Some(outer.id()));
        }
        assert_eq!(current_span(), None);
        let spans = t.drain();
        assert_eq!(spans.len(), 2);
        // Sorted by start: the step span opened first.
        assert_eq!(spans[0].cat, Category::Step);
        assert!(spans[0].start_ns <= spans[1].start_ns);
    }

    #[test]
    fn mem_counter_attributes_delta_to_open_span() {
        let t = Tracer::new(true);
        {
            let _g = t.span(Category::Tile, "sweep");
            note_mem(1024);
            note_mem(-256);
        }
        let s = t.drain().pop().unwrap();
        assert_eq!(s.mem_delta, 768);
        // Counter is cumulative per-thread; neutralize for other tests.
        note_mem(-768);
    }

    #[test]
    fn taxonomy_is_consistent() {
        // tids are the lane contract for the Chrome export: unique, dense.
        let mut tids: Vec<u64> = Category::ALL.iter().map(|c| c.tid()).collect();
        tids.sort_unstable();
        assert_eq!(tids, (0..Category::ALL.len() as u64).collect::<Vec<_>>());
        for c in Category::ALL {
            assert_eq!(c.is_leaf(), Category::LEAVES.contains(&c), "{:?}", c);
            assert_eq!(
                c.is_copy_stream(),
                matches!(c, Category::CopyD2H | Category::CopyH2D)
            );
        }
        // Copy lanes overlap compute; only the stall they induce is a leaf.
        assert!(!Category::CopyD2H.is_leaf());
        assert!(!Category::CopyH2D.is_leaf());
        assert!(Category::Stall.is_leaf());
    }

    #[test]
    fn cancelled_span_is_not_recorded() {
        let t = Tracer::new(true);
        {
            let outer = t.span(Category::Step, "step");
            {
                let mut g = t.span(Category::Collective, "failed_attempt");
                g.set_bytes(4096);
                g.cancel();
                // Cancel pops the nesting stack immediately.
                assert_eq!(current_span(), Some(outer.id()));
            }
        }
        let spans = t.drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].cat, Category::Step);
    }

    #[test]
    fn ids_are_unique_across_threads() {
        let t = Tracer::new(true);
        std::thread::scope(|scope| {
            for r in 0..4 {
                let t = &t;
                scope.spawn(move || {
                    let _s = set_current_rank(Some(r));
                    for i in 0..50 {
                        let mut g = t.span(Category::Exec, "work");
                        g.set_bytes(i);
                    }
                    set_current_rank(None);
                });
            }
        });
        let spans = t.drain();
        assert_eq!(spans.len(), 200);
        let mut ids: Vec<u64> = spans.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 200, "span ids unique under concurrency");
    }
}
