//! Max-seqlen search (the paper's evaluation protocol, §5.3: "zeroing in
//! on the maximum length that does not OOM / NaN").
//!
//! Exponential probe + bisection over the estimator's `fits` predicate,
//! quantized to 1K tokens like the paper's reported numbers.

use crate::memory::Estimator;

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub max_seqlen: usize,
    /// Which resource ended the search ("logits", "ckpt", "mlp",
    /// "attention", "host-ram").
    pub binding: &'static str,
    pub probes: usize,
}

/// Largest sequence length (multiple of `quantum`) that fits.
pub fn max_seqlen_search(est: &Estimator, world: usize) -> SearchOutcome {
    let quantum = 1_000usize;
    let mut probes = 0;
    let mut fits = |s: usize| {
        probes += 1;
        est.fits(s, world)
    };
    if !fits(quantum) {
        return SearchOutcome { max_seqlen: 0, binding: est.binding_constraint(quantum, world), probes };
    }
    // exponential growth to bracket
    let mut lo = quantum;
    let mut hi = quantum * 2;
    while fits(hi) {
        lo = hi;
        hi *= 2;
        if hi > 1 << 32 {
            break;
        }
    }
    // bisect [lo fits, hi doesn't]
    while hi - lo > quantum {
        let mid = (lo + hi) / 2 / quantum * quantum;
        if mid == lo {
            break;
        }
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // report the *next* length's constraint — i.e. what stopped us
    let binding = est.binding_constraint(hi, world);
    SearchOutcome { max_seqlen: lo, binding, probes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;
    use crate::config::{ClusterConfig, FeatureFlags};

    fn search(flags: FeatureFlags, nodes: usize, world: usize) -> SearchOutcome {
        let est = Estimator::new(
            preset("llama3-8b").unwrap(),
            ClusterConfig::h100(nodes),
            flags,
        );
        max_seqlen_search(&est, world)
    }

    #[test]
    fn baseline_is_logits_bound_around_32k() {
        let out = search(FeatureFlags::baseline(), 1, 8);
        // paper Table 1 row 1: 32K
        assert!(out.max_seqlen >= 16_000 && out.max_seqlen <= 64_000, "{out:?}");
        assert_eq!(out.binding, "logits");
    }

    #[test]
    fn alst_beats_baseline_by_orders_of_magnitude() {
        let base = search(FeatureFlags::baseline(), 1, 8).max_seqlen;
        let alst = search(FeatureFlags::alst(), 1, 8).max_seqlen;
        // paper: 32K -> 3.7M is ~116x; require >= 30x for the shape
        assert!(alst > 30 * base, "{base} -> {alst}");
    }

    #[test]
    fn scaling_with_gpus_is_roughly_linear() {
        let s8 = search(FeatureFlags::alst(), 1, 8).max_seqlen;
        let s32 = search(FeatureFlags::alst(), 4, 32).max_seqlen;
        let ratio = s32 as f64 / s8 as f64;
        assert!(ratio > 2.0 && ratio < 8.0, "8->32 GPUs ratio {ratio}");
    }

    #[test]
    fn feature_ladder_is_monotone() {
        let mut prev = 0;
        for (name, flags) in FeatureFlags::table1_ladder() {
            let out = search(flags, 1, 8);
            assert!(
                out.max_seqlen >= prev,
                "{name}: {} < previous {prev}",
                out.max_seqlen
            );
            prev = out.max_seqlen;
        }
    }

    #[test]
    fn host_ram_caps_llama70b() {
        // §5.3.2: Llama-70B ckpt offload saturates 1.9 TiB/node.
        let est = Estimator::new(
            preset("llama3-70b").unwrap(),
            ClusterConfig::h100(4),
            FeatureFlags::alst(),
        );
        let out = max_seqlen_search(&est, 32);
        assert!(out.max_seqlen > 0);
        assert_eq!(out.binding, "host-ram", "{out:?}");
    }

    #[test]
    fn zero_when_nothing_fits() {
        // 70B on one GPU without weight offload cannot even hold states.
        let est = Estimator::new(
            preset("llama3-70b").unwrap(),
            ClusterConfig::h100_single(),
            FeatureFlags::baseline(),
        );
        let out = max_seqlen_search(&est, 1);
        assert_eq!(out.max_seqlen, 0);
    }
}
