//! End-to-end step latency through the real PJRT pipeline (tiny config),
//! plus the coordinator-side hot path that runs with NO artifacts: the
//! per-step relayout cycle through the scratch arena, the scoped-thread
//! rank executor versus the serial loop, and the checkpoint-offload step
//! cycle through the synchronous (inline) versus async double-buffered
//! copy engine (stall/copy/overlap extras in the JSON report).
//!
//! Always emits repo-root `BENCH_pipeline.json` (schema in DESIGN.md);
//! the PJRT sections additionally require `make artifacts` and are
//! skipped gracefully without it.

use std::path::Path;

use alst::collectives::Group;
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{run_ranks, Trainer, TrainerOptions};
use alst::coordinator::ulysses::relayout_step_cycle;
use alst::obs::{Category, Tracer};
use alst::runtime::{HostTensor, Manifest, ScratchArena};
use alst::util::bench::{bench, BenchReport};
use alst::util::rng::Rng;

fn main() {
    let mut report = BenchReport::new("pipeline");
    println!("bench_pipeline: coordinator hot path + PJRT step (if artifacts)\n");

    // ---- coordinator-only: relayout step cycle (no artifacts needed) ----
    let (sp, seq, n_q, n_kv, d, n_layers) = (8usize, 16384usize, 32usize, 4usize, 128usize, 4usize);
    let ssh = seq / sp;
    let mut rng = Rng::new(1);
    let q: Vec<HostTensor> = (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, n_q, d], rng.normal_vec(ssh * n_q * d, 1.0)))
        .collect();
    let kv: Vec<HostTensor> = (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, n_kv, d], rng.normal_vec(ssh * n_kv * d, 1.0)))
        .collect();
    let g = Group::new(sp);
    // this shape's per-layer relayout working set (~1.3 GB pooled at
    // steady state) exceeds the default budget; size the pool to fit so
    // the bench measures the allocation-free path
    let arena = ScratchArena::with_byte_budget(4 << 30);
    // warm one cycle: populates the pool AND measures the exact ledgered
    // wire volume of a cycle (the GiB/s denominator)
    relayout_step_cycle(&g, &arena, &q, &kv, n_layers, n_q, n_kv).unwrap();
    let cycle_bytes = g.stats().all_to_all_bytes;
    g.reset_stats();
    let r = bench(
        &format!("relayout step-cycle sp={sp} seq={seq} L={n_layers} pooled"),
        1,
        10,
        std::time::Duration::from_secs(2),
        || relayout_step_cycle(&g, &arena, &q, &kv, n_layers, n_q, n_kv).unwrap(),
    )
    .with_bytes(cycle_bytes);
    println!(
        "    -> {:.2} GiB/s, arena hit rate {:.4} ({} buffers pooled)",
        r.gib_per_s().unwrap_or(0.0),
        arena.hit_rate(),
        arena.pooled()
    );
    report.push(&r);

    // ---- same cycle with the step tracer recording -----------------------
    // Relayout spans + instant collective spans per a2a; the delta vs the
    // pooled row above is the enabled-tracing overhead on a real hot path.
    let tracer = std::sync::Arc::new(Tracer::new(true));
    let mut gt = Group::new(sp);
    gt.set_tracer(tracer.clone());
    relayout_step_cycle(&gt, &arena, &q, &kv, n_layers, n_q, n_kv).unwrap(); // warm
    let r = bench(
        &format!("relayout step-cycle sp={sp} seq={seq} L={n_layers} traced"),
        1,
        10,
        std::time::Duration::from_secs(2),
        || relayout_step_cycle(&gt, &arena, &q, &kv, n_layers, n_q, n_kv).unwrap(),
    )
    .with_bytes(cycle_bytes);
    println!(
        "    -> {:.2} GiB/s with tracing on ({} spans recorded)",
        r.gib_per_s().unwrap_or(0.0),
        tracer.drain().len()
    );
    report.push(&r);

    // ---- disabled-overhead contract: one branch per span site ------------
    // The row obs/mod.rs pins: a disabled span site must cost a branch and
    // nothing else (no clock read, no lock, no allocation). Measured as
    // 1M guard create/drops per iteration.
    let off = Tracer::off();
    const SITES: u64 = 1_000_000;
    let r = bench(
        "span site (tracer disabled)",
        1,
        10,
        std::time::Duration::from_millis(500),
        || {
            for _ in 0..SITES {
                let s = off.span(Category::Exec, "noop");
                std::hint::black_box(&s);
            }
        },
    );
    println!(
        "    -> {:.3} ns per disabled span site",
        r.mean.as_secs_f64() * 1e9 / SITES as f64
    );
    report.push(&r);

    // ---- coordinator-only: scoped-thread rank executor ------------------
    // A cpu-bound per-rank workload (the shape of per-rank stage calls);
    // serial vs parallel run_ranks on the same closure.
    let work: Vec<Vec<f32>> = (0..sp).map(|_| rng.normal_vec(1 << 18, 1.0)).collect();
    let rank_work = |r: usize| -> anyhow::Result<f64> {
        let mut acc = 0f64;
        for &x in &work[r] {
            acc += (x as f64) * (x as f64);
        }
        Ok(acc)
    };
    for (parallel, label) in [(false, "serial"), (true, "threaded")] {
        let r = bench(
            &format!("run_ranks sp={sp} {label}"),
            1,
            20,
            std::time::Duration::from_millis(500),
            || {
                let out = run_ranks(sp, parallel, rank_work).unwrap();
                std::hint::black_box(out);
            },
        );
        report.push(&r);
    }

    // ---- coordinator-only: offload step cycle, sync vs async -------------
    // The same store/prefetch/fetch schedule the trainer runs, with a
    // cpu-spin standing in for layer compute. Inline mode runs every copy
    // on this thread and counts it as stall (the synchronous reference:
    // stall == copy time); overlap mode runs the copies on the stream
    // workers behind the spins. CI bench-smoke pins async stall < sync
    // copy and overlap_frac > 0 on these rows.
    {
        use alst::coordinator::offload::{
            overlap_frac, AsyncOffloadEngine, OffloadConfig, CKPT_TAG,
        };
        use alst::memory::{HostPool, MemoryTracker};
        use std::sync::Arc;

        let fast = alst::util::bench::fast_mode();
        let (sp_o, ssh_o, hidden_o, layers_o) =
            if fast { (2usize, 256usize, 64usize, 2usize) } else { (4, 8192, 1024, 2) };
        let seq_o = sp_o * ssh_o; // 32K acceptance config in full mode
        let spin_buf = rng.normal_vec(if fast { 1 << 16 } else { 1 << 23 }, 1.0);
        let spin = || {
            let mut acc = 0f64;
            for &x in &spin_buf {
                acc += (x as f64) * (x as f64);
            }
            std::hint::black_box(acc);
        };
        let arena_o = Arc::new(ScratchArena::with_byte_budget(2 << 30));
        let proto =
            HostTensor::f32(vec![ssh_o, hidden_o], rng.normal_vec(ssh_o * hidden_o, 1.0));
        let ckpt_bytes = proto.size_bytes() as u64;
        let cycle_bytes = 2 * (layers_o * sp_o) as u64 * ckpt_bytes; // D2H + H2D

        for (overlap, label) in [(false, "sync(inline)"), (true, "async(overlap)")] {
            let engine = AsyncOffloadEngine::new(
                arena_o.clone(),
                Tracer::off(),
                OffloadConfig { in_flight_cap: 256 << 20, overlap, ..OffloadConfig::default() },
            );
            let mut device = MemoryTracker::new(1 << 40);
            let mut host = HostPool::new(1 << 40);
            let mut cycle = || {
                for li in 0..layers_o {
                    for r in 0..sp_o {
                        engine
                            .store(li, r, arena_o.copy_tensor(&proto), &mut host)
                            .unwrap();
                    }
                    spin(); // the layer compute the D2H copies hide behind
                }
                engine.prefetch_layer(layers_o - 1, sp_o).unwrap();
                spin(); // loss head; the top layer's H2D lands behind it
                for li in (0..layers_o).rev() {
                    for r in 0..sp_o {
                        let t = engine.fetch(li, r, &mut device, &mut host).unwrap();
                        device.free(t.size_bytes() as u64, CKPT_TAG);
                        arena_o.recycle(t);
                    }
                    if li > 0 {
                        engine.prefetch_layer(li - 1, sp_o).unwrap();
                    }
                    spin(); // recompute; the next layer's H2D copies behind it
                }
                engine.drain();
            };
            cycle(); // warm the arena pool
            engine.reset_stats();
            let r = bench(
                &format!("offload step-cycle sp={sp_o} seq={seq_o} L={layers_o} {label}"),
                0,
                5,
                std::time::Duration::from_secs(1),
                &mut cycle,
            );
            let (stalls, stream) = (engine.stalls(), engine.stream_stats());
            let per_iter_ms =
                |d: std::time::Duration| d.as_secs_f64() * 1e3 / r.iters as f64;
            println!(
                "    -> stall {:.3}ms copy {:.3}ms per cycle, overlap_frac {:.2}, \
                 max in-flight {} MiB",
                per_iter_ms(stalls.total()),
                per_iter_ms(stream.copy_time()),
                overlap_frac(&stalls, &stream),
                stream.max_in_flight >> 20,
            );
            let r = r
                .with_bytes(cycle_bytes)
                .with_extra("stall_ms", per_iter_ms(stalls.total()))
                .with_extra("copy_ms", per_iter_ms(stream.copy_time()))
                .with_extra("overlap_frac", overlap_frac(&stalls, &stream));
            report.push(&r);
        }
    }

    // ---- resilience: snapshot cadence cost + recovery latency ------------
    // The chaos harness's unfaulted step is the denominator; the snapshot
    // row carries the amortized per-step overhead for each cadence K, and
    // the recovery row prices a full abort + CRC-checked snapshot load +
    // re-shard restore against one step.
    {
        use alst::config::PlanKind;
        use alst::coordinator::recover::{ChaosConfig, ChaosHarness, Recoverable};

        let fast = alst::util::bench::fast_mode();
        let cfg = ChaosConfig {
            sp: if fast { 2 } else { 4 },
            seq: if fast { 16 } else { 64 },
            n_layers: 2,
            plan: PlanKind::Ulysses,
            threaded: true,
            trace: false,
            fault_plan: None,
            ..ChaosConfig::default()
        };
        let sp_c = cfg.sp;
        let mut h = ChaosHarness::new(cfg).unwrap();
        h.step_once().unwrap(); // warm the arena pool and copy streams
        let r_step = bench(
            &format!("chaos harness step sp={sp_c} unfaulted"),
            1,
            5,
            std::time::Duration::from_secs(1),
            || {
                h.step_once().unwrap();
            },
        );
        let step_ms = r_step.mean.as_secs_f64() * 1e3;
        report.push(&r_step);

        let snap = std::env::temp_dir().join("alst-bench-snapshot.alst");
        let r_save = bench(
            "recovery snapshot write (atomic + crc)",
            1,
            5,
            std::time::Duration::from_millis(500),
            || {
                h.save_snapshot(&snap).unwrap();
            },
        );
        let snap_ms = r_save.mean.as_secs_f64() * 1e3;
        let mut r_save = r_save.with_extra("step_ms", step_ms);
        for k in [1u64, 2, 4, 8] {
            // per-step overhead of snapshotting every K steps
            r_save = r_save.with_extra(&format!("amortized_ms_k{k}"), snap_ms / k as f64);
        }
        println!(
            "    -> snapshot {snap_ms:.3}ms vs step {step_ms:.3}ms \
             ({:.1}% of a step at K=4)",
            100.0 * snap_ms / (4.0 * step_ms.max(1e-9)),
        );
        report.push(&r_save);

        let r_rec = bench(
            "recovery restore (abort + load + re-shard)",
            1,
            5,
            std::time::Duration::from_millis(500),
            || {
                h.abort_inflight();
                h.restore_snapshot(&snap).unwrap();
            },
        );
        let rec_ms = r_rec.mean.as_secs_f64() * 1e3;
        let r_rec = r_rec
            .with_extra("step_ms", step_ms)
            .with_extra("recovery_vs_step", rec_ms / step_ms.max(1e-9));
        println!(
            "    -> recovery {rec_ms:.3}ms = {:.2} steps of lost work ceiling",
            rec_ms / step_ms.max(1e-9),
        );
        report.push(&r_rec);
    }

    // ---- transport overhead: local queues vs socket rank processes -------
    // The same Group collective over both transports: LocalTransport's
    // in-process frame queues versus SocketTransport's spawned rank
    // processes behind Unix-domain sockets (frame header + payload +
    // digest through the kernel, twice — out and echo). The delta is the
    // per-collective price of real process separation.
    {
        use alst::collectives::{SocketOptions, SocketTransport};

        let sp_t = 2usize;
        let shard = rng.normal_vec(4096, 1.0);
        let shards: Vec<&[f32]> = (0..sp_t).map(|_| shard.as_slice()).collect();
        let gather_bytes = (sp_t * shard.len() * 4) as u64;

        let g = Group::new(sp_t);
        g.all_gather(&shards).unwrap(); // warm
        let r_local = bench(
            &format!("all_gather sp={sp_t} n=4096 transport=local"),
            1,
            10,
            std::time::Duration::from_millis(500),
            || {
                let out = g.all_gather(&shards).unwrap();
                std::hint::black_box(out);
            },
        )
        .with_bytes(gather_bytes);
        report.push(&r_local);

        let sopts = SocketOptions {
            worker_bin: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_alst"))),
            ..SocketOptions::default()
        };
        match SocketTransport::spawn(sp_t, sopts, Tracer::off()) {
            Ok(st) => {
                let gs = Group::with_transport(sp_t, st);
                gs.all_gather(&shards).unwrap(); // warm
                let r_sock = bench(
                    &format!("all_gather sp={sp_t} n=4096 transport=socket"),
                    1,
                    10,
                    std::time::Duration::from_millis(500),
                    || {
                        let out = gs.all_gather(&shards).unwrap();
                        std::hint::black_box(out);
                    },
                )
                .with_bytes(gather_bytes);
                let overhead_us =
                    (r_sock.mean.as_secs_f64() - r_local.mean.as_secs_f64()) * 1e6;
                println!(
                    "    -> socket {:.1}us vs local {:.1}us per collective \
                     (+{overhead_us:.1}us for process separation)",
                    r_sock.mean.as_secs_f64() * 1e6,
                    r_local.mean.as_secs_f64() * 1e6,
                );
                let r_sock = r_sock
                    .with_extra("local_mean_us", r_local.mean.as_secs_f64() * 1e6)
                    .with_extra("overhead_us_vs_local", overhead_us);
                report.push(&r_sock);
            }
            Err(e) => eprintln!("SKIP socket transport row: {e:#}"),
        }
    }

    // ---- PJRT sections (need `make artifacts`) ---------------------------
    let dir = Manifest::artifact_dir(Path::new("artifacts"), "tiny", 2, 256);
    if dir.join("manifest.json").exists() {
        println!("\nPJRT step (tiny config, sp=2, seq=256):\n");
        // serial ranks here: the exec/marshal percentage split below sums
        // per-rank stage durations, which only reads as a fraction of the
        // step when ranks don't overlap in wall time
        let opts = TrainerOptions { parallel_ranks: false, ..Default::default() };
        let mut trainer = Trainer::new(&dir, opts).unwrap();
        let mut loader = UlyssesDataLoader::new(MarkovSource::new(512, 256, 0.05, 1), 2);
        let (ids, _) = loader.next();

        // eval (forward only)
        let ids_c = ids.clone();
        trainer.eval_loss(&ids_c).unwrap(); // warm the executable cache
        trainer.engine.reset_stats();
        let r = bench(
            "eval_loss (fwd only)",
            1,
            10,
            std::time::Duration::from_secs(2),
            || {
                trainer.eval_loss(&ids_c).unwrap();
            },
        );
        let st = trainer.engine.stats();
        let exec_frac = st.exec_time.as_secs_f64() / (r.mean.as_secs_f64() * r.iters as f64);
        println!(
            "    -> {} PJRT executions; exec {:.0}% / marshal {:.0}% of step",
            st.executions as usize / r.iters,
            100.0 * exec_frac,
            100.0 * st.marshal_time.as_secs_f64() / (r.mean.as_secs_f64() * r.iters as f64),
        );
        report.push(&r);

        // full train step (fwd + recompute + bwd + optimizer)
        trainer.engine.reset_stats();
        let r = bench(
            "train_step (fwd+bwd+adamw)",
            1,
            10,
            std::time::Duration::from_secs(3),
            || {
                trainer.train_step(&ids).unwrap();
            },
        );
        let st = trainer.engine.stats();
        println!(
            "    -> {} PJRT executions/step; exec {:.1}ms marshal {:.1}ms per step; \
             relayout arena hit rate {:.4}",
            st.executions as usize / r.iters,
            st.exec_time.as_secs_f64() * 1e3 / r.iters as f64,
            st.marshal_time.as_secs_f64() * 1e3 / r.iters as f64,
            trainer.arena().hit_rate(),
        );
        report.push(&r);
    } else {
        eprintln!("\nSKIP PJRT sections: run `make artifacts` first");
    }

    match report.write_repo_root() {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nFAILED to write BENCH_pipeline.json: {e}"),
    }
}
