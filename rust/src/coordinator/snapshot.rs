//! Training-state snapshots: save/restore flat parameters + AdamW state +
//! step counter, so post-training runs can resume (a framework necessity
//! the paper's ArcticTraining recipes rely on) and the resilient trainer
//! can roll back to the last good step after a rank loss.
//!
//! Format v2 (little-endian): magic "ALST", u32 version, u64 step,
//! u64 total_numel, three f32 arrays (params, adam m, adam v), then a
//! CRC32 (IEEE) footer over every preceding byte. Writes go to a sibling
//! temp file and land via atomic rename, so a crash mid-save can never
//! destroy the previous good snapshot. Loads verify the checksum and
//! reject trailing junk; v1 files (no footer) still load.
//!
//! Durability: the temp file is fsynced before the rename and the parent
//! directory is fsynced after it — without the second sync, a crash after
//! rename can roll the directory entry back to the old snapshot (or to
//! nothing) even though the new bytes are on disk. Retention ([`rotate`])
//! keeps the last N step-stamped snapshots beside the live one and GCs
//! older stamps, so a corrupt latest file never strands recovery.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

use crate::coordinator::optimizer::AdamW;
use crate::coordinator::zero::ShardedStore;

const MAGIC: &[u8; 4] = b"ALST";
const VERSION: u32 = 2;
/// Bytes before the f32 arrays: magic + version + step + total.
const HEADER_LEN: usize = 4 + 4 + 8 + 8;

pub struct Snapshot {
    pub step: u64,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, the zlib/PNG polynomial), table built at first use
// ---------------------------------------------------------------------------

/// Advance the raw CRC register (init `0xffff_ffff`, finalize with `!`).
fn crc32_update(mut crc: u32, bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    crc
}

/// CRC32 of a complete byte run (what the footer stores).
fn crc32(bytes: &[u8]) -> u32 {
    !crc32_update(0xffff_ffff, bytes)
}

/// Write adapter that checksums every byte it forwards.
struct Crc32Writer<W: Write> {
    inner: W,
    crc: u32,
}

impl<W: Write> Crc32Writer<W> {
    fn new(inner: W) -> Self {
        Crc32Writer { inner, crc: 0xffff_ffff }
    }

    fn sum(&self) -> u32 {
        !self.crc
    }
}

impl<W: Write> Write for Crc32Writer<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.inner.write_all(buf)?;
        self.crc = crc32_update(self.crc, buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    // one pass, 64KiB chunks to avoid a full byte-copy of the array
    let mut buf = Vec::with_capacity(64 * 1024);
    for chunk in xs.chunks(16 * 1024) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

fn parse_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

/// Save (params, optimizer, step) to `path`: write `<path>.tmp`, then
/// atomically rename over the target. A crash mid-write leaves at worst a
/// stale temp file; the previous snapshot at `path` survives intact.
pub fn save(path: &Path, step: u64, params: &ShardedStore, opt: &AdamW) -> Result<()> {
    let Some(name) = path.file_name() else {
        bail!("snapshot path {} has no file name", path.display());
    };
    let mut tmp_name = name.to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut f = Crc32Writer::new(std::io::BufWriter::new(file));
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&step.to_le_bytes())?;
        f.write_all(&(params.total as u64).to_le_bytes())?;
        write_f32s(&mut f, &params.to_flat())?;
        write_f32s(&mut f, &opt.m.to_flat())?;
        write_f32s(&mut f, &opt.v.to_flat())?;
        // footer goes through the inner writer: the CRC covers everything
        // before it, not itself
        let crc = f.sum();
        f.inner.write_all(&crc.to_le_bytes())?;
        f.inner.flush()?;
        // the bytes must be durable before the rename can publish them
        f.inner.get_ref().sync_all().context("fsync snapshot temp")?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    // the rename itself is a directory mutation: without fsyncing the
    // parent, a crash here can resurrect the old entry (or neither)
    sync_parent(path)?;
    Ok(())
}

/// fsync the directory holding `path` (directory entries are metadata the
/// file's own fsync does not cover).
fn sync_parent(path: &Path) -> Result<()> {
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    std::fs::File::open(parent)
        .and_then(|d| d.sync_all())
        .with_context(|| format!("fsync dir {}", parent.display()))
}

/// Retention: stamp the just-saved snapshot at `path` as a step-suffixed
/// sibling (`<name>.step<step>`, hard link when the filesystem allows,
/// copy otherwise) and GC stamps beyond the newest `keep`. Stamps are
/// full v2 snapshots — `load` opens them directly when the live file is
/// lost or corrupt. Returns the retained stamp paths, newest first.
pub fn rotate(path: &Path, step: u64, keep: usize) -> Result<Vec<PathBuf>> {
    assert!(keep >= 1, "retention needs keep >= 1");
    let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
        bail!("snapshot path {} has no utf-8 file name", path.display());
    };
    let stamped = path.with_file_name(format!("{name}.step{step}"));
    // re-saving the same step replaces its stamp
    std::fs::remove_file(&stamped).ok();
    if std::fs::hard_link(path, &stamped).is_err() {
        std::fs::copy(path, &stamped)
            .with_context(|| format!("stamping {}", stamped.display()))?;
    }
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty()).unwrap_or(Path::new("."));
    let prefix = format!("{name}.step");
    let mut stamps: Vec<(u64, PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let fname = entry.file_name();
        let Some(fname) = fname.to_str() else { continue };
        if let Some(tag) = fname.strip_prefix(&prefix) {
            if let Ok(s) = tag.parse::<u64>() {
                stamps.push((s, entry.path()));
            }
        }
    }
    stamps.sort_by(|a, b| b.0.cmp(&a.0));
    let cut = keep.min(stamps.len());
    for (_, old) in stamps.split_off(cut) {
        std::fs::remove_file(&old).with_context(|| format!("GC {}", old.display()))?;
    }
    sync_parent(path)?;
    Ok(stamps.into_iter().map(|(_, p)| p).collect())
}

/// Load a snapshot; caller re-shards it for the current world size (the
/// snapshot is world-agnostic — resume on a different SP degree works).
/// v2 files are checksum-verified and must end exactly at the footer;
/// v1 files (pre-footer format) load without verification.
pub fn load(path: &Path) -> Result<Snapshot> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut data)
        .with_context(|| format!("reading {}", path.display()))?;
    if data.len() < HEADER_LEN {
        bail!("snapshot truncated (only {} bytes)", data.len());
    }
    if &data[..4] != MAGIC {
        bail!("not an ALST snapshot (bad magic)");
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version == 0 || version > VERSION {
        bail!("unsupported snapshot version {version}");
    }
    let step = u64::from_le_bytes(data[8..16].try_into().unwrap());
    let total = u64::from_le_bytes(data[16..24].try_into().unwrap()) as usize;
    let body = HEADER_LEN + 3 * total * 4;
    let expect_len = if version >= 2 { body + 4 } else { body };
    if data.len() < expect_len {
        bail!(
            "snapshot truncated: {} bytes, {} arrays need {}",
            data.len(),
            total,
            expect_len
        );
    }
    if version >= 2 {
        if data.len() > expect_len {
            bail!(
                "snapshot has {} bytes of trailing junk",
                data.len() - expect_len
            );
        }
        let stored = u32::from_le_bytes(data[body..body + 4].try_into().unwrap());
        let computed = crc32(&data[..body]);
        if stored != computed {
            bail!(
                "snapshot CRC mismatch: stored {stored:#010x}, computed {computed:#010x} \
                 (corrupt file)"
            );
        }
    }
    let params = parse_f32s(&data[HEADER_LEN..HEADER_LEN + total * 4]);
    let m = parse_f32s(&data[HEADER_LEN + total * 4..HEADER_LEN + 2 * total * 4]);
    let v = parse_f32s(&data[HEADER_LEN + 2 * total * 4..HEADER_LEN + 3 * total * 4]);
    Ok(Snapshot { step, params, m, v })
}

/// Restore a snapshot into live training state (re-sharding to `world`).
/// All three arrays are validated against the model's total, so a
/// snapshot with a consistent param array but torn optimizer state is
/// rejected instead of silently corrupting Adam moments.
pub fn restore(
    snap: &Snapshot,
    params: &mut ShardedStore,
    opt: &mut AdamW,
) -> Result<()> {
    if snap.params.len() != params.total {
        bail!(
            "snapshot has {} params, model needs {}",
            snap.params.len(),
            params.total
        );
    }
    for (name, arr) in [("m", &snap.m), ("v", &snap.v)] {
        if arr.len() != params.total {
            bail!(
                "snapshot adam-{name} state has {} entries, model needs {}",
                arr.len(),
                params.total
            );
        }
    }
    let world = params.world();
    *params = ShardedStore::from_flat(&snap.params, world);
    opt.m = ShardedStore::from_flat(&snap.m, world);
    opt.v = ShardedStore::from_flat(&snap.v, world);
    opt.step = snap.step;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::AdamWConfig;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alst-snapshot-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_preserves_everything() {
        let flat: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 7.0).collect();
        let params = ShardedStore::from_flat(&flat, 4);
        let mut opt = AdamW::new(AdamWConfig::default(), 1000, 4);
        opt.step = 42;
        opt.m = ShardedStore::from_flat(&vec![0.25; 1000], 4);
        opt.v = ShardedStore::from_flat(&vec![0.125; 1000], 4);

        let path = tmpfile("roundtrip.alst");
        save(&path, 42, &params, &opt).unwrap();
        let snap = load(&path).unwrap();
        assert_eq!(snap.step, 42);
        assert_eq!(snap.params, flat);
        assert_eq!(snap.m, vec![0.25; 1000]);

        // the temp file was renamed away, not left behind
        assert!(!path.with_file_name("roundtrip.alst.tmp").exists());

        // resume on a DIFFERENT world size
        let mut p2 = ShardedStore::zeros(1000, 8);
        let mut o2 = AdamW::new(AdamWConfig::default(), 1000, 8);
        restore(&snap, &mut p2, &mut o2).unwrap();
        assert_eq!(p2.to_flat(), flat);
        assert_eq!(o2.step, 42);
        assert_eq!(p2.world(), 8);
    }

    #[test]
    fn rejects_wrong_magic_and_size() {
        let path = tmpfile("bad.alst");
        std::fs::write(&path, b"NOPEnope").unwrap();
        assert!(load(&path).is_err());

        let params = ShardedStore::from_flat(&[1.0; 10], 2);
        let opt = AdamW::new(AdamWConfig::default(), 10, 2);
        let path = tmpfile("small.alst");
        save(&path, 1, &params, &opt).unwrap();
        let snap = load(&path).unwrap();
        let mut wrong = ShardedStore::zeros(20, 2);
        let mut o = AdamW::new(AdamWConfig::default(), 20, 2);
        assert!(restore(&snap, &mut wrong, &mut o).is_err());
    }

    #[test]
    fn corrupt_byte_fails_the_crc() {
        let params = ShardedStore::from_flat(&[3.5; 64], 2);
        let opt = AdamW::new(AdamWConfig::default(), 64, 2);
        let path = tmpfile("corrupt.alst");
        save(&path, 5, &params, &opt).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[HEADER_LEN + 17] ^= 0x40; // flip one bit mid-params
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC mismatch"), "got: {err}");
    }

    #[test]
    fn trailing_junk_is_rejected() {
        let params = ShardedStore::from_flat(&[1.0; 16], 2);
        let opt = AdamW::new(AdamWConfig::default(), 16, 2);
        let path = tmpfile("junk.alst");
        save(&path, 2, &params, &opt).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"extra");
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("trailing junk"), "got: {err}");
    }

    #[test]
    fn v1_snapshot_without_footer_still_loads() {
        // hand-build the legacy format: header + arrays, no CRC footer
        let total = 8usize;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&9u64.to_le_bytes());
        bytes.extend_from_slice(&(total as u64).to_le_bytes());
        for arr in 0..3 {
            for i in 0..total {
                bytes.extend_from_slice(&((arr * total + i) as f32).to_le_bytes());
            }
        }
        let path = tmpfile("v1.alst");
        std::fs::write(&path, &bytes).unwrap();
        let snap = load(&path).unwrap();
        assert_eq!(snap.step, 9);
        assert_eq!(snap.params, (0..8).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(snap.v[0], 16.0);
    }

    #[test]
    fn rotation_keeps_last_n_stamps_and_gcs_older() {
        let dir = std::env::temp_dir().join("alst-snapshot-rotate");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.alst");
        let opt = AdamW::new(AdamWConfig::default(), 8, 2);
        for step in [10u64, 20, 30, 40] {
            // distinct params per step so stamps provably hold old bytes
            let params = ShardedStore::from_flat(&[step as f32; 8], 2);
            save(&path, step, &params, &opt).unwrap();
            let kept = rotate(&path, step, 2).unwrap();
            assert!(kept.len() <= 2, "retention budget respected");
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(names.contains(&"ckpt.alst".to_string()));
        assert!(names.contains(&"ckpt.alst.step40".to_string()));
        assert!(names.contains(&"ckpt.alst.step30".to_string()));
        assert!(!names.contains(&"ckpt.alst.step20".to_string()), "GC'd: {names:?}");
        assert!(!names.contains(&"ckpt.alst.step10".to_string()), "GC'd: {names:?}");
        // a stamp is a complete loadable snapshot of ITS step, not a
        // moving alias of the live file
        let old = load(&dir.join("ckpt.alst.step30")).unwrap();
        assert_eq!(old.step, 30);
        assert_eq!(old.params, vec![30.0; 8]);
        // re-stamping the same step is idempotent
        let kept = rotate(&path, 40, 2).unwrap();
        assert_eq!(kept.len(), 2);
        assert!(kept[0].to_string_lossy().ends_with("step40"));
        assert!(kept[1].to_string_lossy().ends_with("step30"));
    }

    #[test]
    fn restore_rejects_torn_optimizer_state() {
        let snap = Snapshot {
            step: 1,
            params: vec![0.0; 12],
            m: vec![0.0; 7], // wrong length
            v: vec![0.0; 12],
        };
        let mut p = ShardedStore::zeros(12, 3);
        let mut o = AdamW::new(AdamWConfig::default(), 12, 3);
        let err = restore(&snap, &mut p, &mut o).unwrap_err().to_string();
        assert!(err.contains("adam-m"), "got: {err}");
    }
}
