//! Training-run metrics: per-step records, loss-curve logging, and the
//! run summaries EXPERIMENTS.md quotes.

use std::io::Write;
use std::path::Path;
use std::time::Duration;

use crate::coordinator::pipeline::{DocumentLoss, PackedStepMetrics, StepMetrics};

/// One document's loss at one step (packed runs only).
#[derive(Debug, Clone)]
pub struct DocLossRecord {
    pub step: u64,
    pub doc: DocumentLoss,
}

#[derive(Debug, Default)]
pub struct RunLog {
    pub steps: Vec<StepMetrics>,
    /// Per-document losses from packed steps (empty for whole-sequence
    /// runs).
    pub doc_losses: Vec<DocLossRecord>,
    /// Cumulative packed-token accounting (real vs padding).
    pub packed_real_tokens: usize,
    pub packed_padding_tokens: usize,
}

impl RunLog {
    pub fn push(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    /// Record a packed step: aggregate metrics plus its per-document
    /// breakdown.
    pub fn push_packed(&mut self, m: PackedStepMetrics) {
        let step = m.metrics.step;
        for doc in m.doc_losses {
            self.doc_losses.push(DocLossRecord { step, doc });
        }
        self.packed_real_tokens += m.real_tokens;
        self.packed_padding_tokens += m.padding_tokens;
        self.steps.push(m.metrics);
    }

    /// Target-weighted mean of per-document losses (weights are each
    /// document's `tokens - 1` trainable targets) — matches the aggregate
    /// loss when every target token weighs equally.
    pub fn mean_doc_loss(&self) -> Option<f32> {
        let mut num = 0f64;
        let mut den = 0f64;
        for r in &self.doc_losses {
            let w = r.doc.tokens.saturating_sub(1) as f64;
            num += r.doc.loss as f64 * w;
            den += w;
        }
        (den > 0.0).then(|| (num / den) as f32)
    }

    /// Fraction of emitted packed tokens that were real documents
    /// (`None` before any packed step). Delegates to the packer's single
    /// definition of efficiency.
    pub fn packing_efficiency(&self) -> Option<f64> {
        let emitted = self.packed_real_tokens + self.packed_padding_tokens;
        (emitted > 0).then(|| {
            crate::packing::PackingStats {
                total_tokens: self.packed_real_tokens,
                padded_tokens: self.packed_padding_tokens,
                ..Default::default()
            }
            .efficiency()
        })
    }

    /// CSV of the per-document breakdown: step,doc_id,tokens,loss
    pub fn doc_loss_csv(&self) -> String {
        let mut s = String::from("step,doc_id,tokens,loss\n");
        for r in &self.doc_losses {
            s.push_str(&format!(
                "{},{},{},{:.6}\n",
                r.step, r.doc.doc_id, r.doc.tokens, r.doc.loss
            ));
        }
        s
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.steps.last().map(|s| s.loss)
    }

    /// Mean loss over the first/last `n` steps (loss-curve trend check).
    pub fn mean_loss_head(&self, n: usize) -> f32 {
        let k = n.min(self.steps.len()).max(1);
        self.steps[..k].iter().map(|s| s.loss).sum::<f32>() / k as f32
    }

    pub fn mean_loss_tail(&self, n: usize) -> f32 {
        let len = self.steps.len();
        let k = n.min(len).max(1);
        self.steps[len - k..].iter().map(|s| s.loss).sum::<f32>() / k as f32
    }

    pub fn mean_step_time(&self) -> Duration {
        if self.steps.is_empty() {
            return Duration::ZERO;
        }
        self.steps.iter().map(|s| s.step_time).sum::<Duration>()
            / self.steps.len() as u32
    }

    pub fn total_tokens(&self) -> usize {
        self.steps.iter().map(|s| s.tokens).sum()
    }

    /// CSV: step,loss,grad_norm,ms,a2a_bytes,send_recv_bytes,
    /// gather_bytes,rs_bytes,ckpt_bytes,device_peak_bytes,retries,
    /// recoveries (the last two are cumulative fault-injection counters;
    /// all-zero columns on runs without an injector)
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "step,loss,grad_norm,step_ms,a2a_bytes,send_recv_bytes,gather_bytes,reduce_scatter_bytes,ckpt_transfer_bytes,device_peak_bytes,retries,recoveries\n",
        );
        for m in &self.steps {
            s.push_str(&format!(
                "{},{:.6},{:.4},{:.1},{},{},{},{},{},{},{},{}\n",
                m.step,
                m.loss,
                m.grad_norm,
                m.step_time.as_secs_f64() * 1e3,
                m.a2a_bytes,
                m.send_recv_bytes,
                m.gather_bytes,
                m.reduce_scatter_bytes,
                m.ckpt_transfer_bytes,
                m.device_peak_bytes,
                m.retries,
                m.recoveries,
            ));
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// ASCII loss curve (examples print this; no plotting deps offline).
    pub fn ascii_loss_curve(&self, width: usize, height: usize) -> String {
        if self.steps.len() < 2 {
            return String::from("(not enough steps)");
        }
        let losses: Vec<f32> = self.steps.iter().map(|s| s.loss).collect();
        let (min, max) = losses
            .iter()
            .fold((f32::MAX, f32::MIN), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        let span = (max - min).max(1e-6);
        let mut grid = vec![vec![b' '; width]; height];
        for (i, &l) in losses.iter().enumerate() {
            let x = i * (width - 1) / (losses.len() - 1);
            let y = ((max - l) / span * (height - 1) as f32).round() as usize;
            grid[y.min(height - 1)][x] = b'*';
        }
        let mut out = String::new();
        out.push_str(&format!("loss {max:.3}\n"));
        for row in grid {
            out.push_str("  |");
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!("     {min:.3} .. steps 1-{}\n", losses.len()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: u64, loss: f32) -> StepMetrics {
        StepMetrics {
            step: i,
            loss,
            grad_norm: 1.0,
            tokens: 128,
            step_time: Duration::from_millis(10),
            a2a_bytes: 0,
            send_recv_bytes: 0,
            gather_bytes: 0,
            reduce_scatter_bytes: 0,
            ckpt_transfer_bytes: 0,
            device_peak_bytes: 0,
            retries: 0,
            recoveries: 0,
        }
    }

    #[test]
    fn trend_helpers() {
        let mut log = RunLog::default();
        for i in 0..10 {
            log.push(step(i, 5.0 - i as f32 * 0.3));
        }
        assert!(log.mean_loss_tail(3) < log.mean_loss_head(3));
        assert_eq!(log.total_tokens(), 1280);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::default();
        let mut m = step(1, 2.5);
        m.device_peak_bytes = 123_456;
        m.retries = 2;
        m.recoveries = 1;
        log.push(m);
        let csv = log.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 2);
        // every StepMetrics field the CSV promises is present, including
        // the measured device peak and the fault counters
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("retries,recoveries"));
        assert_eq!(header.split(',').count(), 12);
        let row = csv.lines().nth(1).unwrap();
        assert_eq!(row.split(',').count(), 12);
        assert!(row.ends_with(",123456,2,1"));
    }

    #[test]
    fn packed_push_aggregates_doc_losses() {
        let mut log = RunLog::default();
        log.push_packed(PackedStepMetrics {
            metrics: step(1, 3.0),
            doc_losses: vec![
                DocumentLoss { doc_id: 7, tokens: 5, loss: 2.0 },
                DocumentLoss { doc_id: 8, tokens: 9, loss: 4.0 },
            ],
            real_tokens: 14,
            padding_tokens: 2,
        });
        assert_eq!(log.steps.len(), 1);
        assert_eq!(log.doc_losses.len(), 2);
        // weights 4 and 8 targets: (2*4 + 4*8) / 12 = 40/12
        let m = log.mean_doc_loss().unwrap();
        assert!((m - 40.0 / 12.0).abs() < 1e-6, "{m}");
        assert!((log.packing_efficiency().unwrap() - 14.0 / 16.0).abs() < 1e-12);
        let csv = log.doc_loss_csv();
        assert!(csv.starts_with("step,doc_id,tokens,loss\n"));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("1,7,5,2.000000"));
    }

    #[test]
    fn empty_log_has_no_packed_summaries() {
        let log = RunLog::default();
        assert!(log.mean_doc_loss().is_none());
        assert!(log.packing_efficiency().is_none());
    }

    #[test]
    fn ascii_curve_renders() {
        let mut log = RunLog::default();
        for i in 0..20 {
            log.push(step(i, (20 - i) as f32));
        }
        let art = log.ascii_loss_curve(40, 8);
        assert!(art.contains('*'));
    }
}
