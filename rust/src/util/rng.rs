//! Deterministic RNG (SplitMix64 + Box-Muller) for parameter init and
//! synthetic data. No external crates; reproducible across runs by seed.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        // SplitMix64
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Derive an independent stream (for per-rank / per-layer seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let a: Vec<u64> = (0..8).map(|_| 0).scan(Rng::new(7), |r, _| Some(r.next_u64())).collect();
        let b: Vec<u64> = (0..8).map(|_| 0).scan(Rng::new(7), |r, _| Some(r.next_u64())).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map(|_| 0).scan(Rng::new(8), |r, _| Some(r.next_u64())).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(3);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
