//! Process-grid and cluster descriptions.

/// Which sequence-parallel attention protocol moves data between the sp
/// ranks of a group. `Ulysses` relayouts seq<->head with all-to-alls and
/// requires `n_heads >= sp`; `Ring` rotates KV blocks rank-to-rank with
/// online-softmax accumulation and has no head bound (Liu et al. 2024,
/// Blockwise RingAttention — see PAPERS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PlanKind {
    #[default]
    Ulysses,
    Ring,
}

impl PlanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PlanKind::Ulysses => "ulysses",
            PlanKind::Ring => "ring",
        }
    }

    /// Parse a CLI/config spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<PlanKind> {
        match s {
            "ulysses" | "a2a" => Some(PlanKind::Ulysses),
            "ring" => Some(PlanKind::Ring),
            _ => None,
        }
    }
}

/// DP x SP process grid (paper §7.1: scale beyond the SP head-limit with
/// more DP replicas — "1024 GPUs = 16 replicas of SP=64").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    pub dp: usize,
    pub sp: usize,
    /// Attention protocol used inside each SP group.
    pub plan: PlanKind,
}

impl ParallelConfig {
    pub fn new(dp: usize, sp: usize) -> Self {
        assert!(dp >= 1 && sp >= 1);
        ParallelConfig { dp, sp, plan: PlanKind::Ulysses }
    }

    pub fn with_plan(mut self, plan: PlanKind) -> Self {
        self.plan = plan;
        self
    }

    pub fn world_size(&self) -> usize {
        self.dp * self.sp
    }

    /// rank -> (dp_index, sp_index); SP groups are contiguous ranks, which
    /// keeps the latency-critical all-to-all intra-node whenever sp <= 8.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.world_size());
        (rank / self.sp, rank % self.sp)
    }

    pub fn rank_of(&self, dp: usize, sp: usize) -> usize {
        assert!(dp < self.dp && sp < self.sp);
        dp * self.sp + sp
    }

    /// Ranks in the same SP group as `rank`.
    pub fn sp_group(&self, rank: usize) -> Vec<usize> {
        let (dp, _) = self.coords(rank);
        (0..self.sp).map(|s| self.rank_of(dp, s)).collect()
    }

    /// Ranks in the same DP group (same sp index across replicas).
    pub fn dp_group(&self, rank: usize) -> Vec<usize> {
        let (_, sp) = self.coords(rank);
        (0..self.dp).map(|d| self.rank_of(d, sp)).collect()
    }
}

/// Hardware description for the memory simulator + perf model.
/// Defaults mirror the paper's testbed (§5.2).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub gpus_per_node: usize,
    pub n_nodes: usize,
    /// Per-GPU device memory (H100 80GB: 80 GiB).
    pub gpu_mem_bytes: u64,
    /// Host memory per node usable for offload (paper: 1.9 TiB).
    pub host_mem_bytes: u64,
    /// Intra-node interconnect (NVLink-4: 450 GB/s per the paper).
    pub intra_bw_bytes_per_s: f64,
    /// Inter-node fabric (EFA v2: ~200 GB/s all-reduce throughput).
    pub inter_bw_bytes_per_s: f64,
    /// Host<->device bandwidth for offload traffic (PCIe gen5 ~50 GB/s
    /// effective per direction).
    pub pcie_bw_bytes_per_s: f64,
    /// Peak bf16 compute per GPU (H100 SXM dense: 989 TFLOPS).
    pub peak_flops: f64,
}

pub const GIB: u64 = 1 << 30;

impl ClusterConfig {
    /// The paper's testbed: N nodes of 8x H100-80GB, 1.9 TiB host RAM,
    /// NVLink-4 + EFA v2.
    pub fn h100(n_nodes: usize) -> Self {
        ClusterConfig {
            gpus_per_node: 8,
            n_nodes,
            gpu_mem_bytes: 80 * GIB,
            host_mem_bytes: (1.9 * (1u64 << 40) as f64) as u64,
            intra_bw_bytes_per_s: 450e9,
            inter_bw_bytes_per_s: 200e9,
            pcie_bw_bytes_per_s: 50e9,
            peak_flops: 989e12,
        }
    }

    /// Single-GPU development box (1 GPU, same part).
    pub fn h100_single() -> Self {
        ClusterConfig { gpus_per_node: 1, ..Self::h100(1) }
    }

    pub fn total_gpus(&self) -> usize {
        self.gpus_per_node * self.n_nodes
    }

    /// Bandwidth seen by a collective spanning `ranks` GPUs.
    pub fn collective_bw(&self, ranks: usize) -> f64 {
        if ranks <= self.gpus_per_node {
            self.intra_bw_bytes_per_s
        } else {
            self.inter_bw_bytes_per_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_round_trips() {
        let p = ParallelConfig::new(4, 8);
        assert_eq!(p.world_size(), 32);
        for r in 0..32 {
            let (d, s) = p.coords(r);
            assert_eq!(p.rank_of(d, s), r);
        }
    }

    #[test]
    fn sp_groups_are_contiguous() {
        let p = ParallelConfig::new(2, 4);
        assert_eq!(p.sp_group(5), vec![4, 5, 6, 7]);
        assert_eq!(p.dp_group(5), vec![1, 5]);
    }

    #[test]
    fn plan_kind_defaults_and_parses() {
        assert_eq!(PlanKind::default(), PlanKind::Ulysses);
        assert_eq!(ParallelConfig::new(1, 8).plan, PlanKind::Ulysses);
        assert_eq!(
            ParallelConfig::new(1, 8).with_plan(PlanKind::Ring).plan,
            PlanKind::Ring
        );
        assert_eq!(PlanKind::parse("ring"), Some(PlanKind::Ring));
        assert_eq!(PlanKind::parse("ulysses"), Some(PlanKind::Ulysses));
        assert_eq!(PlanKind::parse("a2a"), Some(PlanKind::Ulysses));
        assert_eq!(PlanKind::parse("mesh"), None);
        assert_eq!(PlanKind::Ring.as_str(), "ring");
    }

    #[test]
    fn h100_cluster_matches_paper() {
        let c = ClusterConfig::h100(4);
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.gpu_mem_bytes, 80 * GIB);
        assert!(c.collective_bw(8) > c.collective_bw(16));
    }
}
