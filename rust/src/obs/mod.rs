//! Observability: the unified step tracer.
//!
//! The repo's four telemetry surfaces (`EngineStats`, `CommStats`,
//! `MemoryTracker`, arena hit/miss) are scalar ledgers — they say *how
//! much* but never *when* or *which rank*. This module adds the missing
//! timeline: structured spans recorded by a lock-sharded [`Tracer`],
//! exported as Chrome trace-event JSON ([`chrome`]) and summarized as a
//! per-step attribution table ([`report`]) whose category sums reconcile
//! with the existing ledgers (see `tests/obs_trace.rs`).
//!
//! Everything hangs off one `Arc<Tracer>` created by the `Trainer` when
//! `TrainerOptions::trace` is set (or by the `trace` subcommand) and
//! installed into the engine, the collectives group, the memory tracker,
//! the checkpoint tape, and the tile drivers. When tracing is off the
//! shared [`Tracer::off`] handle is installed instead and every span site
//! costs one branch — no allocation, no clock read, no lock (pinned by
//! the `span site (tracer disabled)` row in `BENCH_pipeline.json`).

pub mod chrome;
pub mod report;
pub mod tracer;

pub use chrome::{trace_events, validate_trace, write_trace, COORD_PID};
pub use report::{AttributionReport, CatTotals, MemPeak, StepAttribution};
pub use tracer::{
    current_rank, current_span, note_mem, rank_scope, set_current_rank, Category, MemEvent,
    RankScope, Span, SpanGuard, Tracer,
};
