//! The transport seam under `Group`: framed, checksummed, deadline-bounded
//! point-to-point moves (DESIGN.md §Transport).
//!
//! A collective in this codebase is a deterministic relayout with a byte
//! ledger; the [`Transport`] trait is where those bytes actually travel.
//! Every frame is length-prefixed and carries an FNV-1a digest (the same
//! per-transfer convention the offload engine's checked copies use), every
//! blocking call takes an explicit [`Deadline`], and peer death is a typed
//! signal (`AlstError::LostRank`), never a hang.
//!
//! Two implementations:
//!
//! * [`LocalTransport`] — in-process queues behind a mutex+condvar, the
//!   refactored home of the previous behavior. Pinned bit-identical: a
//!   frame's f32 payload round-trips untouched, so every pre-transport
//!   equivalence test still holds over it.
//! * [`SocketTransport`] — Unix-domain sockets to spawned rank worker
//!   processes (`alst rank-worker`). The coordinator keeps the god view
//!   (all ranks' buffers, as everywhere else in the crate); each frame is
//!   relayed through its *source* rank's process and echoed back, so the
//!   payload genuinely crosses two process boundaries and a SIGKILLed,
//!   truncating, or hung worker produces a real socket-level failure. A
//!   liveness heartbeat runs on an idle side-channel per rank: a peer
//!   that stops beating past `heartbeat_timeout` is declared lost even if
//!   its data socket never errors — a *hung* peer is distinguished from a
//!   *slow* one (which keeps beating while ops time out as retryable
//!   `Transient`s).
//!
//! Error mapping (real I/O → `AlstError`, site `Wire`):
//! ECONNRESET/EPIPE/EOF-at-frame-boundary/heartbeat-expiry → `LostRank`;
//! deadline or socket timeout → `Transient` (retryable); checksum
//! mismatch or torn frame (EOF mid-payload) → `CorruptPayload`
//! (retryable; the retry against a dead peer then surfaces `LostRank`).
//! `run_resilient` therefore fires identically whether the fault came
//! from a `FaultInjector` or a killed rank process.

use std::collections::HashMap;
use std::fmt;
use std::io::{self, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::faults::{checksum_f32s, lock_clean, AlstError, FaultSite};
use crate::obs::{Category, Tracer};

// ---------------------------------------------------------------------------
// Deadline
// ---------------------------------------------------------------------------

/// An absolute time bound on a blocking call. `never()` is the unbounded
/// sentinel (used only by paths that are bounded transitively); everything
/// on the wire should carry `after(op_timeout)` so a lost peer surfaces as
/// a typed error instead of a deadlock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Deadline {
    at: Option<Instant>,
}

impl Deadline {
    pub fn after(d: Duration) -> Deadline {
        Deadline { at: Some(Instant::now() + d) }
    }

    pub fn never() -> Deadline {
        Deadline { at: None }
    }

    pub fn expired(&self) -> bool {
        self.at.is_some_and(|t| Instant::now() >= t)
    }

    /// Time left, saturating at zero. `None` means unbounded.
    pub fn remaining(&self) -> Option<Duration> {
        self.at.map(|t| t.saturating_duration_since(Instant::now()))
    }

    /// The value to hand `set_read_timeout`/`set_write_timeout`/`wait_timeout`:
    /// `None` for unbounded, otherwise the remainder clamped up to 1ms so a
    /// just-expiring deadline still makes one bounded syscall (passing a
    /// zero timeout to the socket APIs is an error).
    pub fn io_timeout(&self) -> Option<Duration> {
        self.remaining().map(|r| r.max(Duration::from_millis(1)))
    }
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    Local,
    Socket,
}

impl TransportKind {
    pub fn as_str(self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Socket => "socket",
        }
    }
}

impl std::str::FromStr for TransportKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<TransportKind, String> {
        match s {
            "local" => Ok(TransportKind::Local),
            "socket" => Ok(TransportKind::Socket),
            other => Err(format!("unknown transport {other:?} (expected local|socket)")),
        }
    }
}

/// Framed point-to-point transport between `world` ranks. `send` frames a
/// payload (length prefix + FNV-1a digest) addressed `src → dst` and
/// returns the frame's sequence number; `recv_into` blocks for exactly
/// that frame, verifying length and digest. Both are deadline-bounded.
/// `check_peers` is the liveness gate every collective runs before moving
/// data: a dead or heartbeat-expired peer is a typed `LostRank`.
pub trait Transport: Send + Sync + fmt::Debug {
    fn kind(&self) -> TransportKind;

    fn world(&self) -> usize;

    /// Frame and transmit `payload` from `src` to `dst`. Returns the frame
    /// sequence number the matching `recv_into` must wait for.
    fn send(
        &self,
        src: usize,
        dst: usize,
        payload: &[f32],
        deadline: Deadline,
    ) -> std::result::Result<u64, AlstError>;

    /// Receive frame `frame` (from an earlier `send(src, dst, ..)`) into
    /// `out`, which must match the payload length exactly. Frames older
    /// than `frame` still in flight (a timed-out attempt's late echo) are
    /// discarded; a length or digest mismatch is `CorruptPayload`.
    fn recv_into(
        &self,
        src: usize,
        dst: usize,
        frame: u64,
        out: &mut [f32],
        deadline: Deadline,
    ) -> std::result::Result<(), AlstError>;

    /// Liveness gate: typed `LostRank` if any peer is dead, closed, or
    /// heartbeat-expired. Cheap enough to run before every collective.
    fn check_peers(&self) -> std::result::Result<(), AlstError>;

    /// Frames transmitted via `rank` so far (diagnostics; chaos tests use
    /// it to aim worker fail points at a mid-step frame index).
    fn frames_via(&self, rank: usize) -> u64;

    /// Graceful shutdown: workers are told to exit; later ops fail typed.
    fn close(&self);
}

fn lost(rank: usize) -> AlstError {
    AlstError::LostRank { site: FaultSite::Wire, rank }
}

fn expired(rank: usize) -> AlstError {
    AlstError::Transient { site: FaultSite::Wire, rank, attempt: 0 }
}

fn torn(rank: usize, expect: u64, got: u64) -> AlstError {
    AlstError::CorruptPayload { site: FaultSite::Wire, rank, expect, got }
}

// ---------------------------------------------------------------------------
// LocalTransport
// ---------------------------------------------------------------------------

struct LocalFrame {
    seq: u64,
    checksum: u64,
    payload: Vec<f32>,
}

/// In-process transport: frames queue between ranks under one mutex, a
/// condvar wakes blocked receivers, and payload buffers recycle through a
/// size-keyed pool so steady-state traffic allocates nothing (the caller's
/// `ScratchArena` accounting is untouched — the pool is transport-private).
/// Test hooks (`fail_peer`, `corrupt_next_frames`) model peer death and
/// wire corruption without a chaos injector.
pub struct LocalTransport {
    world: usize,
    queues: Mutex<HashMap<(usize, usize), std::collections::VecDeque<LocalFrame>>>,
    cv: Condvar,
    pool: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    seq: AtomicU64,
    frames: Vec<AtomicU64>,
    dead: Vec<AtomicBool>,
    closed: AtomicBool,
    corrupt_next: AtomicU64,
}

impl fmt::Debug for LocalTransport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LocalTransport").field("world", &self.world).finish()
    }
}

impl LocalTransport {
    pub fn new(world: usize) -> Arc<LocalTransport> {
        assert!(world >= 1);
        Arc::new(LocalTransport {
            world,
            queues: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            pool: Mutex::new(HashMap::new()),
            seq: AtomicU64::new(0),
            frames: (0..world).map(|_| AtomicU64::new(0)).collect(),
            dead: (0..world).map(|_| AtomicBool::new(false)).collect(),
            closed: AtomicBool::new(false),
            corrupt_next: AtomicU64::new(0),
        })
    }

    /// Declare `rank` dead: the typed peer-death signal, locally testable.
    pub fn fail_peer(&self, rank: usize) {
        self.dead[rank].store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    pub fn revive_peer(&self, rank: usize) {
        self.dead[rank].store(false, Ordering::SeqCst);
    }

    /// Flip one bit in each of the next `n` frames *after* the sender
    /// digested them — wire corruption the receiver's verify must catch.
    pub fn corrupt_next_frames(&self, n: u64) {
        self.corrupt_next.store(n, Ordering::SeqCst);
    }

    fn take_pooled(&self, len: usize) -> Vec<f32> {
        let mut pool = lock_clean(&self.pool);
        pool.get_mut(&len).and_then(Vec::pop).unwrap_or_else(|| vec![0.0; len])
    }

    fn reclaim(&self, buf: Vec<f32>) {
        if !buf.is_empty() {
            lock_clean(&self.pool).entry(buf.len()).or_default().push(buf);
        }
    }

    fn wait_queues<'a>(
        &'a self,
        guard: MutexGuard<'a, HashMap<(usize, usize), std::collections::VecDeque<LocalFrame>>>,
        timeout: Option<Duration>,
    ) -> (MutexGuard<'a, HashMap<(usize, usize), std::collections::VecDeque<LocalFrame>>>, bool)
    {
        match timeout {
            Some(t) => {
                let (g, r) = self
                    .cv
                    .wait_timeout(guard, t)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                (g, r.timed_out())
            }
            None => {
                let g = self.cv.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner);
                (g, false)
            }
        }
    }

    fn peer_gate(&self, src: usize, dst: usize) -> std::result::Result<(), AlstError> {
        for r in [src, dst] {
            if self.dead[r].load(Ordering::SeqCst) {
                return Err(lost(r));
            }
        }
        if self.closed.load(Ordering::SeqCst) {
            return Err(lost(dst));
        }
        Ok(())
    }
}

impl Transport for LocalTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Local
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(
        &self,
        src: usize,
        dst: usize,
        payload: &[f32],
        _deadline: Deadline,
    ) -> std::result::Result<u64, AlstError> {
        assert!(src < self.world && dst < self.world);
        self.peer_gate(src, dst)?;
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let checksum = checksum_f32s(payload);
        let mut buf = self.take_pooled(payload.len());
        buf.copy_from_slice(payload);
        if self.corrupt_next.load(Ordering::SeqCst) > 0 && !buf.is_empty() {
            self.corrupt_next.fetch_sub(1, Ordering::SeqCst);
            buf[0] = f32::from_bits(buf[0].to_bits() ^ 1);
        }
        lock_clean(&self.queues)
            .entry((src, dst))
            .or_default()
            .push_back(LocalFrame { seq, checksum, payload: buf });
        self.frames[src].fetch_add(1, Ordering::SeqCst);
        self.cv.notify_all();
        Ok(seq)
    }

    fn recv_into(
        &self,
        src: usize,
        dst: usize,
        frame: u64,
        out: &mut [f32],
        deadline: Deadline,
    ) -> std::result::Result<(), AlstError> {
        // Wait: no matching frame yet. Got/Fail end the scan either way.
        enum Scan {
            Got(LocalFrame),
            Fail(AlstError),
            Wait,
        }
        let entry = loop {
            self.peer_gate(src, dst)?;
            let mut stale: Vec<Vec<f32>> = Vec::new();
            let mut guard = lock_clean(&self.queues);
            let verdict = {
                let q = guard.entry((src, dst)).or_default();
                loop {
                    match q.front() {
                        Some(f) if f.seq < frame => {
                            stale.push(q.pop_front().expect("front exists").payload);
                        }
                        Some(f) if f.seq == frame => {
                            break Scan::Got(q.pop_front().expect("front exists"));
                        }
                        // a frame from the future: ours was dropped
                        Some(f) => break Scan::Fail(torn(src, frame, f.seq)),
                        None => break Scan::Wait,
                    }
                }
            };
            let verdict = match verdict {
                Scan::Wait if deadline.expired() => Scan::Fail(expired(src)),
                Scan::Wait => {
                    let (g, _) = self.wait_queues(guard, deadline.io_timeout());
                    guard = g;
                    Scan::Wait
                }
                v => v,
            };
            drop(guard);
            for buf in stale {
                self.reclaim(buf);
            }
            match verdict {
                Scan::Got(entry) => break entry,
                Scan::Fail(e) => return Err(e),
                Scan::Wait => continue,
            }
        };
        if entry.payload.len() != out.len() {
            let got = entry.payload.len() as u64;
            self.reclaim(entry.payload);
            return Err(torn(src, out.len() as u64, got));
        }
        out.copy_from_slice(&entry.payload);
        let got = checksum_f32s(out);
        self.reclaim(entry.payload);
        if got != entry.checksum {
            return Err(torn(src, entry.checksum, got));
        }
        Ok(())
    }

    fn check_peers(&self) -> std::result::Result<(), AlstError> {
        for (r, d) in self.dead.iter().enumerate() {
            if d.load(Ordering::SeqCst) {
                return Err(lost(r));
            }
        }
        Ok(())
    }

    fn frames_via(&self, rank: usize) -> u64 {
        self.frames[rank].load(Ordering::SeqCst)
    }

    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Wire format (shared by SocketTransport and the rank worker)
// ---------------------------------------------------------------------------

const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"ALSF");
const HEADER_LEN: usize = 25; // magic u32 | kind u8 | src u16 | dst u16 | seq u64 | len u64

const KIND_DATA: u8 = 0;
const KIND_SHUTDOWN: u8 = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct FrameHeader {
    kind: u8,
    src: u16,
    dst: u16,
    seq: u64,
    /// Payload byte count (f32 little-endian stream; digest follows it).
    len: u64,
}

impl FrameHeader {
    fn encode(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        b[4] = self.kind;
        b[5..7].copy_from_slice(&self.src.to_le_bytes());
        b[7..9].copy_from_slice(&self.dst.to_le_bytes());
        b[9..17].copy_from_slice(&self.seq.to_le_bytes());
        b[17..25].copy_from_slice(&self.len.to_le_bytes());
        b
    }

    fn decode(b: &[u8; HEADER_LEN]) -> Option<FrameHeader> {
        if u32::from_le_bytes(b[0..4].try_into().expect("4 bytes")) != FRAME_MAGIC {
            return None;
        }
        Some(FrameHeader {
            kind: b[4],
            src: u16::from_le_bytes(b[5..7].try_into().expect("2 bytes")),
            dst: u16::from_le_bytes(b[7..9].try_into().expect("2 bytes")),
            seq: u64::from_le_bytes(b[9..17].try_into().expect("8 bytes")),
            len: u64::from_le_bytes(b[17..25].try_into().expect("8 bytes")),
        })
    }
}

fn encode_payload(payload: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(payload.len() * 4);
    for x in payload {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    bytes
}

fn decode_payload(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len() * 4);
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = f32::from_le_bytes(c.try_into().expect("4 bytes"));
    }
}

// ---------------------------------------------------------------------------
// Worker side (runs in the spawned rank process — or a thread in tests)
// ---------------------------------------------------------------------------

/// How a worker misbehaves, for deterministic *real* fault injection: the
/// failure happens in another process, on a real socket, at a chosen frame
/// index — the socket-era analogue of `FaultPlan`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerFailMode {
    /// Die without echoing (process mode: hard exit, the coordinator sees
    /// EOF at a frame boundary → `LostRank`).
    Kill,
    /// Echo half the payload, then die (torn frame → `CorruptPayload`,
    /// whose retry against the dead peer surfaces `LostRank`).
    Truncate,
    /// Flip one payload bit in a single echo, then behave (the digest
    /// catches it → `CorruptPayload`, absorbed by retry in place).
    CorruptOnce,
    /// Keep the data socket alive but stop heartbeating: the hung-peer
    /// case only the side-channel can detect.
    StallHeartbeat,
}

impl std::str::FromStr for WorkerFailMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<WorkerFailMode, String> {
        match s {
            "kill" => Ok(WorkerFailMode::Kill),
            "truncate" => Ok(WorkerFailMode::Truncate),
            "corrupt-once" => Ok(WorkerFailMode::CorruptOnce),
            "stall-heartbeat" => Ok(WorkerFailMode::StallHeartbeat),
            other => Err(format!("unknown fail mode {other:?}")),
        }
    }
}

impl WorkerFailMode {
    pub fn as_str(self) -> &'static str {
        match self {
            WorkerFailMode::Kill => "kill",
            WorkerFailMode::Truncate => "truncate",
            WorkerFailMode::CorruptOnce => "corrupt-once",
            WorkerFailMode::StallHeartbeat => "stall-heartbeat",
        }
    }
}

/// One planned worker failure: `rank`'s worker misbehaves after echoing
/// (or beating, for `StallHeartbeat`) `after` frames/beats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerFailure {
    pub rank: usize,
    pub mode: WorkerFailMode,
    pub after: u64,
}

/// Everything a rank worker needs; built from CLI args by `alst
/// rank-worker` (process mode) or passed directly (in-thread mode).
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub rank: usize,
    pub main_path: PathBuf,
    pub hb_path: PathBuf,
    pub hb_interval: Duration,
    pub connect_timeout: Duration,
    /// This worker's own failure plan (already filtered to its rank).
    pub failure: Option<WorkerFailure>,
    /// Process mode: `Kill`/`Truncate` hard-exit the process. Thread mode
    /// returns instead (closing the sockets models the death).
    pub exit_hard: bool,
}

fn connect_retry(path: &Path, timeout: Duration) -> io::Result<UnixStream> {
    let start = Instant::now();
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if start.elapsed() >= timeout {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// The worker loop: connect both channels, pump heartbeats from a side
/// thread, and echo every data frame back — each echo is the "wire
/// delivery" leg of a frame that already crossed one real process
/// boundary on the way in. Returns when the coordinator shuts down the
/// channel (or on a planned failure).
pub fn run_worker(cfg: &WorkerConfig) -> Result<()> {
    let mut main = connect_retry(&cfg.main_path, cfg.connect_timeout)
        .with_context(|| format!("rank {} connect {}", cfg.rank, cfg.main_path.display()))?;
    let hb = connect_retry(&cfg.hb_path, cfg.connect_timeout)
        .with_context(|| format!("rank {} connect {}", cfg.rank, cfg.hb_path.display()))?;

    let stall_after = match cfg.failure {
        Some(WorkerFailure { mode: WorkerFailMode::StallHeartbeat, after, .. }) => Some(after),
        _ => None,
    };
    let hb_interval = cfg.hb_interval;
    // The heartbeat pump owns its stream; it dies with the connection.
    std::thread::spawn(move || {
        let mut hb = hb;
        let mut beat = 0u64;
        loop {
            if stall_after.is_some_and(|n| beat >= n) {
                // hung, not dead: the data socket stays open while the
                // side-channel falls silent
                std::thread::sleep(Duration::from_secs(3600));
                continue;
            }
            if hb.write_all(&beat.to_le_bytes()).is_err() || hb.flush().is_err() {
                return;
            }
            beat += 1;
            std::thread::sleep(hb_interval);
        }
    });

    let mut frames = 0u64;
    let mut payload: Vec<u8> = Vec::new();
    loop {
        let mut hdr_bytes = [0u8; HEADER_LEN];
        if main.read_exact(&mut hdr_bytes).is_err() {
            return Ok(()); // coordinator gone
        }
        let Some(hdr) = FrameHeader::decode(&hdr_bytes) else {
            anyhow::bail!("rank {}: bad frame magic", cfg.rank);
        };
        if hdr.kind == KIND_SHUTDOWN {
            return Ok(());
        }
        payload.resize(hdr.len as usize, 0);
        main.read_exact(&mut payload).context("payload")?;
        let mut digest = [0u8; 8];
        main.read_exact(&mut digest).context("digest")?;
        frames += 1;
        if let Some(f) = cfg.failure {
            if frames > f.after {
                match f.mode {
                    WorkerFailMode::Kill => {
                        if cfg.exit_hard {
                            std::process::exit(9);
                        }
                        return Ok(());
                    }
                    WorkerFailMode::Truncate => {
                        let _ = main.write_all(&hdr_bytes);
                        let _ = main.write_all(&payload[..payload.len() / 2]);
                        let _ = main.flush();
                        if cfg.exit_hard {
                            std::process::exit(9);
                        }
                        return Ok(());
                    }
                    WorkerFailMode::CorruptOnce => {
                        if frames == f.after + 1 && !payload.is_empty() {
                            payload[0] ^= 1;
                        }
                    }
                    WorkerFailMode::StallHeartbeat => {}
                }
            }
        }
        main.write_all(&hdr_bytes).context("echo header")?;
        main.write_all(&payload).context("echo payload")?;
        main.write_all(&digest).context("echo digest")?;
        main.flush().context("echo flush")?;
    }
}

// ---------------------------------------------------------------------------
// SocketTransport (coordinator side)
// ---------------------------------------------------------------------------

/// Knobs for [`SocketTransport::spawn`]. All timeouts are deliberately
/// conservative defaults; chaos tests shrink them so "no test hangs past
/// its deadline" is enforced by construction.
#[derive(Debug, Clone)]
pub struct SocketOptions {
    /// Worker binary (must understand `rank-worker`). `None`: the
    /// `ALST_WORKER_BIN` env var, else `current_exe()` — integration
    /// tests pass `env!("CARGO_BIN_EXE_alst")` explicitly.
    pub worker_bin: Option<PathBuf>,
    /// Bound on worker spawn/connect/accept during `spawn` and `heal`.
    pub connect_timeout: Duration,
    /// Worker heartbeat period on the side-channel.
    pub heartbeat_interval: Duration,
    /// Silence on the side-channel past this declares the peer hung.
    pub heartbeat_timeout: Duration,
    /// Deterministic real-fault plan shipped to one worker.
    pub failure: Option<WorkerFailure>,
    /// Run workers as in-process threads over the same real sockets
    /// (unit tests); `false` spawns rank processes.
    pub in_thread: bool,
}

impl Default for SocketOptions {
    fn default() -> SocketOptions {
        SocketOptions {
            worker_bin: None,
            connect_timeout: Duration::from_secs(10),
            heartbeat_interval: Duration::from_millis(50),
            heartbeat_timeout: Duration::from_secs(5),
            failure: None,
            in_thread: false,
        }
    }
}

enum WorkerHandle {
    Process(Child),
    Thread(std::thread::JoinHandle<()>),
}

impl fmt::Debug for WorkerHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WorkerHandle::Process(c) => write!(f, "Process(pid {})", c.id()),
            WorkerHandle::Thread(_) => write!(f, "Thread"),
        }
    }
}

struct HbState {
    stream: UnixStream,
    /// Bytes of a beat received so far (beats are 8-byte frames; a
    /// nonblocking drain can split one).
    partial: usize,
    last_beat: Instant,
    beats: u64,
}

struct Peer {
    main: Mutex<UnixStream>,
    hb: Mutex<HbState>,
    child: Mutex<Option<WorkerHandle>>,
    dead: AtomicBool,
    /// Framing lost (timeout mid-frame, bad magic, torn payload): the
    /// channel can't be trusted even though the process may live. `heal`
    /// respawns tainted ranks along with dead ones.
    tainted: AtomicBool,
    frames: AtomicU64,
}

static SOCK_DIR_ID: AtomicU64 = AtomicU64::new(0);

/// Coordinator side of the socket transport: one spawned worker, one data
/// socket, and one heartbeat socket per rank. See the module docs for the
/// relay model and error mapping.
pub struct SocketTransport {
    world: usize,
    opts: SocketOptions,
    dir: PathBuf,
    peers: Vec<Peer>,
    /// Path generation per rank, bumped on heal so rebinds never collide.
    gens: Vec<AtomicU64>,
    seq: AtomicU64,
    tracer: Arc<Tracer>,
    closed: AtomicBool,
}

impl fmt::Debug for SocketTransport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SocketTransport")
            .field("world", &self.world)
            .field("dir", &self.dir)
            .finish()
    }
}

fn accept_deadline(listener: &UnixListener, deadline: Deadline) -> io::Result<UnixStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                return Ok(s);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if deadline.expired() {
                    return Err(io::Error::new(io::ErrorKind::TimedOut, "accept timed out"));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Real I/O error → the typed taxonomy. `mid_frame` distinguishes a torn
/// frame (EOF after the header landed — retryable `CorruptPayload`) from
/// a clean connection loss (`LostRank`).
fn map_io(kind: io::ErrorKind, rank: usize, mid_frame: bool) -> AlstError {
    use io::ErrorKind::*;
    match kind {
        TimedOut | WouldBlock => expired(rank),
        UnexpectedEof if mid_frame => torn(rank, 0, 0),
        UnexpectedEof | ConnectionReset | ConnectionAborted | BrokenPipe | NotConnected => {
            lost(rank)
        }
        _ => expired(rank),
    }
}

impl SocketTransport {
    /// Bind sockets, launch one worker per rank, and wait (bounded) for
    /// both channels of each to connect.
    pub fn spawn(
        world: usize,
        opts: SocketOptions,
        tracer: Arc<Tracer>,
    ) -> Result<Arc<SocketTransport>> {
        assert!(world >= 1);
        let dir = std::env::temp_dir().join(format!(
            "alst-sock-{}-{}",
            std::process::id(),
            SOCK_DIR_ID.fetch_add(1, Ordering::SeqCst)
        ));
        std::fs::create_dir_all(&dir).context("create socket dir")?;
        let mut peers = Vec::with_capacity(world);
        for rank in 0..world {
            peers.push(launch_rank(&dir, rank, 0, &opts, opts.failure)?);
        }
        Ok(Arc::new(SocketTransport {
            world,
            opts,
            dir,
            peers,
            gens: (0..world).map(|_| AtomicU64::new(0)).collect(),
            seq: AtomicU64::new(0),
            tracer,
            closed: AtomicBool::new(false),
        }))
    }

    pub fn heartbeat_timeout(&self) -> Duration {
        self.opts.heartbeat_timeout
    }

    /// Heartbeats seen from `rank` (diagnostics).
    pub fn beats_from(&self, rank: usize) -> u64 {
        lock_clean(&self.peers[rank].hb).beats
    }

    /// SIGKILL `rank`'s worker process (no-op for in-thread workers): the
    /// genuinely external kill the acceptance contract names. The death is
    /// then *detected*, not assumed — EOF on the data socket or silence on
    /// the side-channel.
    pub fn kill_rank(&self, rank: usize) {
        if let Some(WorkerHandle::Process(child)) = lock_clean(&self.peers[rank].child).as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Respawn every dead or tainted rank with a clean worker (no failure
    /// plan — the replacement is healthy) on fresh socket paths. The
    /// recovery path runs this before restoring a snapshot, so a killed
    /// rank process heals the same way a simulated `LostRank` disarms.
    /// Returns the number of ranks respawned.
    pub fn heal(&self) -> Result<usize> {
        let mut healed = 0;
        for rank in 0..self.world {
            let p = &self.peers[rank];
            if !p.dead.load(Ordering::SeqCst) && !p.tainted.load(Ordering::SeqCst) {
                continue;
            }
            reap(&mut *lock_clean(&p.child));
            let gen = self.gens[rank].fetch_add(1, Ordering::SeqCst) + 1;
            let fresh = launch_rank(&self.dir, rank, gen, &self.opts, None)?;
            *lock_clean(&p.main) = fresh.main.into_inner().expect("fresh mutex");
            *lock_clean(&p.hb) = fresh.hb.into_inner().expect("fresh mutex");
            *lock_clean(&p.child) = fresh.child.into_inner().expect("fresh mutex");
            p.frames.store(0, Ordering::SeqCst);
            p.tainted.store(false, Ordering::SeqCst);
            p.dead.store(false, Ordering::SeqCst);
            healed += 1;
        }
        Ok(healed)
    }

    fn mark(&self, rank: usize, e: &AlstError) {
        match e {
            AlstError::LostRank { .. } => {
                self.peers[rank].dead.store(true, Ordering::SeqCst);
            }
            _ => {
                self.peers[rank].tainted.store(true, Ordering::SeqCst);
            }
        }
    }
}

/// Bind, spawn, accept one rank's worker (generation `gen` of its paths).
fn launch_rank(
    dir: &Path,
    rank: usize,
    gen: u64,
    opts: &SocketOptions,
    failure: Option<WorkerFailure>,
) -> Result<Peer> {
    let main_path = dir.join(format!("r{rank}-g{gen}.main"));
    let hb_path = dir.join(format!("r{rank}-g{gen}.hb"));
    let main_listener = UnixListener::bind(&main_path)
        .with_context(|| format!("bind {}", main_path.display()))?;
    let hb_listener =
        UnixListener::bind(&hb_path).with_context(|| format!("bind {}", hb_path.display()))?;
    let cfg = WorkerConfig {
        rank,
        main_path,
        hb_path,
        hb_interval: opts.heartbeat_interval,
        connect_timeout: opts.connect_timeout,
        failure: failure.filter(|f| f.rank == rank),
        exit_hard: !opts.in_thread,
    };
    let child = if opts.in_thread {
        let thread_cfg = cfg.clone();
        WorkerHandle::Thread(std::thread::spawn(move || {
            let _ = run_worker(&thread_cfg);
        }))
    } else {
        let bin = match &opts.worker_bin {
            Some(b) => b.clone(),
            None => match std::env::var_os("ALST_WORKER_BIN") {
                Some(v) => PathBuf::from(v),
                None => std::env::current_exe().context("resolve worker bin")?,
            },
        };
        let mut cmd = Command::new(&bin);
        cmd.arg("rank-worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--main")
            .arg(&cfg.main_path)
            .arg("--hb")
            .arg(&cfg.hb_path)
            .arg("--hb-interval-us")
            .arg(opts.heartbeat_interval.as_micros().to_string())
            .arg("--connect-timeout-ms")
            .arg(opts.connect_timeout.as_millis().to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null());
        if let Some(f) = cfg.failure {
            cmd.arg("--fail-mode")
                .arg(f.mode.as_str())
                .arg("--fail-after")
                .arg(f.after.to_string());
        }
        WorkerHandle::Process(
            cmd.spawn().with_context(|| format!("spawn worker {}", bin.display()))?,
        )
    };
    let deadline = Deadline::after(opts.connect_timeout);
    let main = accept_deadline(&main_listener, deadline)
        .with_context(|| format!("rank {rank} main channel accept"))?;
    let hb = accept_deadline(&hb_listener, deadline)
        .with_context(|| format!("rank {rank} heartbeat channel accept"))?;
    hb.set_nonblocking(true).context("heartbeat nonblocking")?;
    Ok(Peer {
        main: Mutex::new(main),
        hb: Mutex::new(HbState { stream: hb, partial: 0, last_beat: Instant::now(), beats: 0 }),
        child: Mutex::new(Some(child)),
        dead: AtomicBool::new(false),
        tainted: AtomicBool::new(false),
        frames: AtomicU64::new(0),
    })
}

fn reap(handle: &mut Option<WorkerHandle>) {
    match handle.take() {
        Some(WorkerHandle::Process(mut child)) => {
            let _ = child.kill();
            let _ = child.wait();
        }
        // The thread worker exits on its own once its streams are
        // replaced/dropped (EOF); joining here could block on a stalled
        // heartbeat sleeper, so detach.
        Some(WorkerHandle::Thread(_)) | None => {}
    }
}

impl Transport for SocketTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Socket
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(
        &self,
        src: usize,
        dst: usize,
        payload: &[f32],
        deadline: Deadline,
    ) -> std::result::Result<u64, AlstError> {
        assert!(src < self.world && dst < self.world);
        if self.closed.load(Ordering::SeqCst) {
            return Err(lost(src));
        }
        let peer = &self.peers[src]; // frames travel via their source rank
        if peer.dead.load(Ordering::SeqCst) {
            return Err(lost(src));
        }
        if deadline.expired() {
            return Err(expired(src));
        }
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let hdr = FrameHeader {
            kind: KIND_DATA,
            src: src as u16,
            dst: dst as u16,
            seq,
            len: (payload.len() * 4) as u64,
        };
        let digest = checksum_f32s(payload);
        let bytes = encode_payload(payload);
        let mut stream = lock_clean(&peer.main);
        stream.set_write_timeout(deadline.io_timeout()).ok();
        let write = stream
            .write_all(&hdr.encode())
            .and_then(|_| stream.write_all(&bytes))
            .and_then(|_| stream.write_all(&digest.to_le_bytes()))
            .and_then(|_| stream.flush());
        if let Err(e) = write {
            let mapped = map_io(e.kind(), src, false);
            self.mark(src, &mapped);
            return Err(mapped);
        }
        peer.frames.fetch_add(1, Ordering::SeqCst);
        Ok(seq)
    }

    fn recv_into(
        &self,
        src: usize,
        dst: usize,
        frame: u64,
        out: &mut [f32],
        deadline: Deadline,
    ) -> std::result::Result<(), AlstError> {
        let peer = &self.peers[src];
        if peer.dead.load(Ordering::SeqCst) {
            return Err(lost(src));
        }
        let t0 = Instant::now();
        let result = (|| {
            let mut stream = lock_clean(&peer.main);
            let mut scratch: Vec<u8> = Vec::new();
            loop {
                if deadline.expired() {
                    return Err(expired(src));
                }
                stream.set_read_timeout(deadline.io_timeout()).ok();
                let mut hdr_bytes = [0u8; HEADER_LEN];
                read_exact_or(&mut *stream, &mut hdr_bytes, src, false)?;
                let Some(hdr) = FrameHeader::decode(&hdr_bytes) else {
                    return Err(torn(src, FRAME_MAGIC as u64, 0));
                };
                scratch.resize(hdr.len as usize, 0);
                read_exact_or(&mut *stream, &mut scratch, src, true)?;
                let mut digest_bytes = [0u8; 8];
                read_exact_or(&mut *stream, &mut digest_bytes, src, true)?;
                if hdr.seq < frame {
                    continue; // late echo of a timed-out attempt
                }
                if hdr.seq > frame
                    || hdr.src as usize != src
                    || hdr.dst as usize != dst
                    || hdr.len as usize != out.len() * 4
                {
                    return Err(torn(src, frame, hdr.seq));
                }
                decode_payload(&scratch, out);
                let expect = u64::from_le_bytes(digest_bytes);
                let got = checksum_f32s(out);
                if got != expect {
                    return Err(AlstError::CorruptPayload {
                        site: FaultSite::Wire,
                        rank: src,
                        expect,
                        got,
                    });
                }
                return Ok(());
            }
        })();
        if self.tracer.enabled() {
            let mut sp = self.tracer.span(Category::Stall, "wire_wait");
            sp.set_rank(src);
            sp.set_bytes((out.len() * 4) as u64);
            sp.set_dur(t0.elapsed());
        }
        if let Err(e) = &result {
            self.mark(src, e);
        }
        result
    }

    fn check_peers(&self) -> std::result::Result<(), AlstError> {
        for rank in 0..self.world {
            let p = &self.peers[rank];
            if p.dead.load(Ordering::SeqCst) {
                return Err(lost(rank));
            }
            let mut hb = lock_clean(&p.hb);
            let mut buf = [0u8; 256];
            loop {
                match hb.stream.read(&mut buf) {
                    Ok(0) => {
                        drop(hb);
                        p.dead.store(true, Ordering::SeqCst);
                        return Err(lost(rank));
                    }
                    Ok(n) => {
                        hb.partial += n;
                        hb.beats += (hb.partial / 8) as u64;
                        hb.partial %= 8;
                        hb.last_beat = Instant::now();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        drop(hb);
                        p.dead.store(true, Ordering::SeqCst);
                        return Err(lost(rank));
                    }
                }
            }
            if hb.last_beat.elapsed() > self.opts.heartbeat_timeout {
                drop(hb);
                p.dead.store(true, Ordering::SeqCst);
                if self.tracer.enabled() {
                    let mut sp = self.tracer.span(Category::Fault, "heartbeat_expired");
                    sp.set_rank(rank);
                    sp.set_dur(Duration::ZERO);
                }
                return Err(lost(rank));
            }
        }
        Ok(())
    }

    fn frames_via(&self, rank: usize) -> u64 {
        self.peers[rank].frames.load(Ordering::SeqCst)
    }

    fn close(&self) {
        if self.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        let shutdown =
            FrameHeader { kind: KIND_SHUTDOWN, src: 0, dst: 0, seq: u64::MAX, len: 0 }.encode();
        for p in &self.peers {
            if !p.dead.load(Ordering::SeqCst) {
                let mut s = lock_clean(&p.main);
                s.set_write_timeout(Some(Duration::from_millis(100))).ok();
                let _ = s.write_all(&shutdown);
                let _ = s.flush();
            }
        }
    }
}

fn read_exact_or(
    stream: &mut UnixStream,
    buf: &mut [u8],
    rank: usize,
    mid_frame: bool,
) -> std::result::Result<(), AlstError> {
    stream.read_exact(buf).map_err(|e| map_io(e.kind(), rank, mid_frame))
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.close();
        for p in &self.peers {
            reap(&mut *lock_clean(&p.child));
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DL: Duration = Duration::from_secs(5);

    fn sock(world: usize, opts: SocketOptions) -> Arc<SocketTransport> {
        SocketTransport::spawn(
            world,
            SocketOptions { in_thread: true, ..opts },
            Tracer::off(),
        )
        .unwrap()
    }

    fn fast_hb(opts: SocketOptions) -> SocketOptions {
        SocketOptions {
            heartbeat_interval: Duration::from_millis(5),
            heartbeat_timeout: Duration::from_millis(250),
            ..opts
        }
    }

    #[test]
    fn deadline_semantics() {
        let never = Deadline::never();
        assert!(!never.expired());
        assert_eq!(never.remaining(), None);
        assert_eq!(never.io_timeout(), None);
        let soon = Deadline::after(Duration::from_millis(50));
        assert!(!soon.expired());
        assert!(soon.io_timeout().unwrap() >= Duration::from_millis(1));
        let past = Deadline::after(Duration::ZERO);
        std::thread::sleep(Duration::from_millis(2));
        assert!(past.expired());
        assert_eq!(past.remaining(), Some(Duration::ZERO));
        // a just-expired deadline still yields a valid (1ms) io timeout
        assert_eq!(past.io_timeout(), Some(Duration::from_millis(1)));
    }

    #[test]
    fn frame_header_round_trips() {
        let h = FrameHeader { kind: KIND_DATA, src: 3, dst: 1, seq: 0xdead_beef, len: 48 };
        assert_eq!(FrameHeader::decode(&h.encode()), Some(h));
        let mut bad = h.encode();
        bad[0] ^= 0xff;
        assert_eq!(FrameHeader::decode(&bad), None);
    }

    fn roundtrip_bit_exact(t: &dyn Transport) {
        let payload = vec![1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE, -3.25e30];
        let frame = t.send(0, 1, &payload, Deadline::after(DL)).unwrap();
        let mut out = vec![0.0f32; payload.len()];
        t.recv_into(0, 1, frame, &mut out, Deadline::after(DL)).unwrap();
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&payload), bits(&out), "payload must round-trip bit-exactly");
        assert_eq!(t.frames_via(0), 1);
        assert_eq!(t.frames_via(1), 0);
    }

    #[test]
    fn local_roundtrip_is_bit_exact() {
        roundtrip_bit_exact(&*LocalTransport::new(2));
    }

    #[test]
    fn socket_roundtrip_is_bit_exact() {
        roundtrip_bit_exact(&*sock(2, SocketOptions::default()));
    }

    #[test]
    fn local_recv_deadline_expires_to_transient() {
        let t = LocalTransport::new(2);
        let mut out = [0.0f32; 1];
        let t0 = Instant::now();
        let err = t
            .recv_into(0, 1, 0, &mut out, Deadline::after(Duration::from_millis(30)))
            .unwrap_err();
        assert!(matches!(err, AlstError::Transient { site: FaultSite::Wire, rank: 0, .. }));
        assert!(err.is_retryable());
        assert!(t0.elapsed() < Duration::from_secs(2), "deadline bounded the wait");
    }

    #[test]
    fn socket_recv_deadline_expires_to_transient() {
        let t = sock(1, SocketOptions::default());
        let mut out = [0.0f32; 1];
        // nothing was sent, so nothing echoes: the read must time out
        let err = t
            .recv_into(0, 0, 0, &mut out, Deadline::after(Duration::from_millis(50)))
            .unwrap_err();
        assert!(matches!(err, AlstError::Transient { site: FaultSite::Wire, .. }));
    }

    #[test]
    fn local_peer_death_is_typed_everywhere() {
        let t = LocalTransport::new(3);
        t.check_peers().unwrap();
        t.fail_peer(2);
        assert_eq!(t.check_peers().unwrap_err(), lost(2));
        assert_eq!(t.send(2, 0, &[1.0], Deadline::after(DL)).unwrap_err(), lost(2));
        assert_eq!(t.send(0, 2, &[1.0], Deadline::after(DL)).unwrap_err(), lost(2));
        // a blocked recv wakes up when the peer dies mid-wait
        let t2 = LocalTransport::new(2);
        let t2c = t2.clone();
        let killer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            t2c.fail_peer(0);
        });
        let mut out = [0.0f32; 1];
        let err = t2.recv_into(0, 1, 0, &mut out, Deadline::after(DL)).unwrap_err();
        assert_eq!(err, lost(0));
        killer.join().unwrap();
    }

    #[test]
    fn local_checksum_rejection_is_corrupt_payload() {
        let t = LocalTransport::new(2);
        t.corrupt_next_frames(1);
        let frame = t.send(0, 1, &[1.0, 2.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 2];
        let err = t.recv_into(0, 1, frame, &mut out, Deadline::after(DL)).unwrap_err();
        assert!(matches!(err, AlstError::CorruptPayload { site: FaultSite::Wire, .. }));
        assert!(err.is_retryable());
        // the wire is clean again afterwards
        let frame = t.send(0, 1, &[3.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 1];
        t.recv_into(0, 1, frame, &mut out, Deadline::after(DL)).unwrap();
        assert_eq!(out, [3.0]);
    }

    #[test]
    fn local_stale_frames_are_discarded() {
        let t = LocalTransport::new(2);
        let _old = t.send(0, 1, &[9.0], Deadline::after(DL)).unwrap();
        let fresh = t.send(0, 1, &[7.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 1];
        t.recv_into(0, 1, fresh, &mut out, Deadline::after(DL)).unwrap();
        assert_eq!(out, [7.0], "the stale frame was skipped, not delivered");
    }

    #[test]
    fn socket_kill_surfaces_lost_rank_and_heal_respawns() {
        let t = sock(
            2,
            SocketOptions {
                failure: Some(WorkerFailure { rank: 1, mode: WorkerFailMode::Kill, after: 1 }),
                ..SocketOptions::default()
            },
        );
        // frame 1 echoes fine
        let f = t.send(1, 0, &[1.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 1];
        t.recv_into(1, 0, f, &mut out, Deadline::after(DL)).unwrap();
        // frame 2 is swallowed: the worker dies, EOF at a frame boundary
        let f = t.send(1, 0, &[2.0], Deadline::after(DL)).unwrap();
        let err = t.recv_into(1, 0, f, &mut out, Deadline::after(DL)).unwrap_err();
        assert_eq!(err, lost(1));
        assert_eq!(t.check_peers().unwrap_err(), lost(1));
        // heal respawns a clean worker and the wire works again
        assert_eq!(t.heal().unwrap(), 1);
        t.check_peers().unwrap();
        let f = t.send(1, 0, &[5.0], Deadline::after(DL)).unwrap();
        t.recv_into(1, 0, f, &mut out, Deadline::after(DL)).unwrap();
        assert_eq!(out, [5.0]);
        assert_eq!(t.frames_via(1), 1, "frame counter reset with the respawn");
    }

    #[test]
    fn socket_truncated_frame_is_torn_then_lost() {
        let t = sock(
            2,
            SocketOptions {
                failure: Some(WorkerFailure { rank: 0, mode: WorkerFailMode::Truncate, after: 0 }),
                ..SocketOptions::default()
            },
        );
        let f = t.send(0, 1, &[1.0, 2.0, 3.0, 4.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 4];
        let err = t.recv_into(0, 1, f, &mut out, Deadline::after(DL)).unwrap_err();
        assert!(
            matches!(err, AlstError::CorruptPayload { site: FaultSite::Wire, .. }),
            "EOF mid-payload is a torn frame, got {err:?}"
        );
        assert!(err.is_retryable());
        // the retry hits the dead peer: LostRank
        let err = t.send(0, 1, &[1.0], Deadline::after(DL)).unwrap_err();
        assert_eq!(err, lost(0));
    }

    #[test]
    fn socket_corrupt_once_is_caught_then_clean() {
        let t = sock(
            2,
            SocketOptions {
                failure: Some(WorkerFailure {
                    rank: 0,
                    mode: WorkerFailMode::CorruptOnce,
                    after: 0,
                }),
                ..SocketOptions::default()
            },
        );
        let f = t.send(0, 1, &[1.0, 2.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 2];
        let err = t.recv_into(0, 1, f, &mut out, Deadline::after(DL)).unwrap_err();
        assert!(matches!(err, AlstError::CorruptPayload { site: FaultSite::Wire, .. }));
        // retransmit succeeds: the worker only corrupted one echo
        let f = t.send(0, 1, &[1.0, 2.0], Deadline::after(DL)).unwrap();
        t.recv_into(0, 1, f, &mut out, Deadline::after(DL)).unwrap();
        assert_eq!(out, [1.0, 2.0]);
    }

    #[test]
    fn socket_stalled_heartbeat_is_hung_not_slow() {
        let t = sock(
            2,
            fast_hb(SocketOptions {
                failure: Some(WorkerFailure {
                    rank: 1,
                    mode: WorkerFailMode::StallHeartbeat,
                    after: 2,
                }),
                ..SocketOptions::default()
            }),
        );
        // the data channel still works while the side-channel dies down
        let f = t.send(1, 0, &[4.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 1];
        t.recv_into(1, 0, f, &mut out, Deadline::after(DL)).unwrap();
        // poll liveness until the beat gap crosses the timeout
        let t0 = Instant::now();
        let err = loop {
            match t.check_peers() {
                Ok(()) => {
                    assert!(
                        t0.elapsed() < Duration::from_secs(10),
                        "stalled heartbeat never declared lost"
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => break e,
            }
        };
        assert_eq!(err, lost(1));
        // rank 0 kept beating the whole time
        assert!(t.beats_from(0) >= 2, "healthy peer's beats were consumed");
    }

    #[test]
    fn socket_close_shuts_workers_down() {
        let t = sock(2, SocketOptions::default());
        let f = t.send(0, 1, &[1.0], Deadline::after(DL)).unwrap();
        let mut out = [0.0f32; 1];
        t.recv_into(0, 1, f, &mut out, Deadline::after(DL)).unwrap();
        t.close();
        assert!(matches!(t.send(0, 1, &[1.0], Deadline::after(DL)), Err(AlstError::LostRank { .. })));
    }
}
