"""Blocked causal attention (FlashAttention-2 style) as a Pallas kernel.

The paper does not tile attention (it *cannot* be sequence-tiled — every
query needs the whole key space; §3.1 fn.11) and instead leans on
FlashAttention-2's internal blocking. This kernel plays that role in the
ALST-RS stack: the Ulysses attention stage calls it on `[S, H_shard, D]`
head-sharded tensors after the all-to-all, so the coordinator stays
attention-agnostic (swap this for `ref.attention_naive` and nothing else
changes — the paper's central claim).

Hardware adaptation: FA2's shared-memory score tile becomes a `[TQ, TK]`
VMEM tile; the warp-level online softmax becomes running (m, l, acc)
revisited-output accumulators across the k-tile grid axis.

GQA/MQA is handled in the BlockSpec index map: q head `h` reads kv head
`h // (Hq // Hkv)` — no materialized `jnp.repeat`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, o_ref,
                 *, tile_q: int, tile_k: int, scale: float, n_k: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...][:, 0, :]                       # [TQ, D]
    k = k_ref[...][:, 0, :]                       # [TK, D]
    v = v_ref[...][:, 0, :]
    scores = (q @ k.T) * scale                    # [TQ, TK] — the VMEM tile

    q_ids = i * tile_q + jax.lax.iota(jnp.int32, tile_q)
    k_ids = j * tile_k + jax.lax.iota(jnp.int32, tile_k)
    causal = q_ids[:, None] >= k_ids[None, :]
    scores = jnp.where(causal, scores, NEG_INF)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, scores.max(axis=-1))
    # Masked-out entries must contribute exactly 0 (not exp(NEG_INF - m)).
    p = jnp.where(causal, jnp.exp(scores - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / l_ref[...][:, None])[:, None, :]


def flash_attention(q, k, v, *, tile_q: int = 128, tile_k: int = 128,
                    interpret: bool = True):
    """Causal attention. q: [S, Hq, D]; k, v: [S, Hkv, D]; Hq % Hkv == 0."""
    s, hq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    tile_q, tile_k = min(tile_q, s), min(tile_k, s)
    assert s % tile_q == 0 and s % tile_k == 0, (s, tile_q, tile_k)
    n_q, n_k = s // tile_q, s // tile_k
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _attn_kernel, tile_q=tile_q, tile_k=tile_k, scale=scale, n_k=n_k
    )
    _, _, _, o = pl.pallas_call(
        kernel,
        grid=(hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((tile_q, 1, d), lambda h, i, j: (i, h, 0)),
            pl.BlockSpec((tile_k, 1, d), lambda h, i, j: (j, h // rep, 0)),
            pl.BlockSpec((tile_k, 1, d), lambda h, i, j: (j, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, d), lambda h, i, j: (i, 0)),   # acc scratch
            pl.BlockSpec((tile_q,), lambda h, i, j: (i,)),       # m scratch
            pl.BlockSpec((tile_q,), lambda h, i, j: (i,)),       # l scratch
            pl.BlockSpec((tile_q, 1, d), lambda h, i, j: (i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, d), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s, hq, d), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v)
    return o


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(q, k, v, tile_q: int = 128, tile_k: int = 128):
    """Blocked causal attention with a reference-recompute backward."""
    return flash_attention(q, k, v, tile_q=tile_q, tile_k=tile_k)


def _attn_fwd(q, k, v, tile_q, tile_k):
    return flash_attention(q, k, v, tile_q=tile_q, tile_k=tile_k), (q, k, v)


def _attn_bwd(tile_q, tile_k, res, d_o):
    # Backward recomputes through the reference formulation; at CPU-PJRT
    # validation scales (S <= a few K) the [S, S] score matrix is cheap,
    # and the paper itself delegates attention-bwd memory to FA2.
    q, k, v = res
    _, vjp = jax.vjp(ref.attention_naive, q, k, v)
    return vjp(d_o)


attention.defvjp(_attn_fwd, _attn_bwd)
