//! AdamW over ZeRO-sharded flat parameters.
//!
//! Each rank updates only its owned shard (ZeRO-3), so the optimizer is
//! embarrassingly local; states can be "offloaded" to the host pool (the
//! paper's DeepSpeed optimizer-state CPU offload, on in every evaluated
//! config) — in the simulator that moves 12 bytes/param off the device.

use crate::coordinator::zero::ShardedStore;

#[derive(Debug, Clone, Copy)]
pub struct AdamWConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Global gradient-norm clip (0 = off).
    pub grad_clip: f32,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig {
            lr: 3e-4,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 1.0,
        }
    }
}

/// Sharded AdamW state (m, v mirror the parameter sharding).
pub struct AdamW {
    pub cfg: AdamWConfig,
    pub step: u64,
    pub m: ShardedStore,
    pub v: ShardedStore,
}

impl AdamW {
    pub fn new(cfg: AdamWConfig, total: usize, world: usize) -> AdamW {
        AdamW {
            cfg,
            step: 0,
            m: ShardedStore::zeros(total, world),
            v: ShardedStore::zeros(total, world),
        }
    }

    /// Global grad L2 norm across all shards (the all-reduce every rank
    /// would do before clipping).
    pub fn global_grad_norm(grads: &ShardedStore) -> f64 {
        grads
            .shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|&g| (g as f64) * (g as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// One decoupled-weight-decay Adam step over every owned shard.
    /// Returns the (pre-clip) global gradient norm.
    pub fn step(&mut self, params: &mut ShardedStore, grads: &ShardedStore) -> f64 {
        assert_eq!(params.total, grads.total);
        self.step += 1;
        let t = self.step as i32;
        let c = self.cfg;
        let norm = Self::global_grad_norm(grads);
        let clip_scale = if c.grad_clip > 0.0 && norm > c.grad_clip as f64 {
            (c.grad_clip as f64 / norm) as f32
        } else {
            1.0
        };
        let bc1 = 1.0 - c.beta1.powi(t);
        let bc2 = 1.0 - c.beta2.powi(t);

        for r in 0..params.world() {
            let p = &mut params.shards[r];
            let g = &grads.shards[r];
            let m = &mut self.m.shards[r];
            let v = &mut self.v.shards[r];
            // Tail padding of the last shard has zero grads; harmless, but
            // avoid decaying padding values (they are already 0).
            for i in 0..p.len() {
                let gi = g[i] * clip_scale;
                m[i] = c.beta1 * m[i] + (1.0 - c.beta1) * gi;
                v[i] = c.beta2 * v[i] + (1.0 - c.beta2) * gi * gi;
                let m_hat = m[i] / bc1;
                let v_hat = v[i] / bc2;
                p[i] -= c.lr * (m_hat / (v_hat.sqrt() + c.eps) + c.weight_decay * p[i]);
            }
        }
        norm
    }

    /// Optimizer-state bytes per rank (device or host depending on the
    /// offload flag): fp32 m + v = 8 bytes/param-shard element.
    pub fn state_bytes_per_rank(&self) -> u64 {
        2 * self.m.shard_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_setup(world: usize) -> (ShardedStore, AdamW) {
        let params = ShardedStore::from_flat(&[5.0, -3.0, 2.0, 8.0], world);
        let opt = AdamW::new(
            AdamWConfig { lr: 0.1, weight_decay: 0.0, grad_clip: 0.0, ..Default::default() },
            4,
            world,
        );
        (params, opt)
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize sum(x^2)/2; grad = x
        let (mut params, mut opt) = quadratic_setup(2);
        for _ in 0..300 {
            let grads = ShardedStore::from_flat(&params.to_flat(), 2);
            opt.step(&mut params, &grads);
        }
        for x in params.to_flat() {
            assert!(x.abs() < 1e-2, "x={x}");
        }
    }

    #[test]
    fn sharding_invariance() {
        // Same trajectory whether sharded over 1 or 4 ranks.
        let (mut p1, mut o1) = quadratic_setup(1);
        let (mut p4, mut o4) = quadratic_setup(4);
        for _ in 0..10 {
            let g1 = ShardedStore::from_flat(&p1.to_flat(), 1);
            let g4 = ShardedStore::from_flat(&p4.to_flat(), 4);
            o1.step(&mut p1, &g1);
            o4.step(&mut p4, &g4);
        }
        let (a, b) = (p1.to_flat(), p4.to_flat());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn grad_clip_bounds_update() {
        let mut params = ShardedStore::from_flat(&[0.0; 4], 1);
        let mut opt = AdamW::new(
            AdamWConfig { lr: 1.0, grad_clip: 1.0, weight_decay: 0.0, ..Default::default() },
            4,
            1,
        );
        let grads = ShardedStore::from_flat(&[1e6, -1e6, 1e6, -1e6], 1);
        let norm = opt.step(&mut params, &grads);
        assert!(norm > 1e6);
        for x in params.to_flat() {
            assert!(x.abs() < 1.1); // clipped step is bounded by lr
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut params = ShardedStore::from_flat(&[10.0], 1);
        let mut opt = AdamW::new(
            AdamWConfig { lr: 0.1, weight_decay: 0.5, grad_clip: 0.0, ..Default::default() },
            1,
            1,
        );
        let grads = ShardedStore::zeros(1, 1);
        opt.step(&mut params, &grads);
        let x = params.to_flat()[0];
        assert!(x < 10.0 && x > 9.0);
    }
}
