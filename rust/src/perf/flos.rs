//! flos (floating-point operations — the BLOOM-coined spelling the paper
//! adopts, fn.22) for one training iteration at batch size 1.
//!
//! Forward per layer: QKVO projections + attention scores/values + SwiGLU
//! MLP; plus the logits matmul once. Training = 3x forward (fwd + bwd)
//! + 1x forward again when activation checkpointing recomputes (§5.4's
//! "repeated forwards" — our backward literally re-runs the layer).

use crate::config::ModelPreset;

#[derive(Debug, Clone, Default)]
pub struct FlosBreakdown {
    pub proj: f64,
    pub attention: f64,
    pub mlp: f64,
    pub logits: f64,
}

impl FlosBreakdown {
    pub fn forward_total(&self) -> f64 {
        self.proj + self.attention + self.mlp + self.logits
    }

    /// Fraction of forward flos spent in attention — the paper's "at such
    /// long sequence lengths attention renders MLP compute negligible".
    pub fn attention_fraction(&self) -> f64 {
        self.attention / self.forward_total()
    }
}

/// Forward flos for ONE layer at sequence length `s` (batch 1).
pub fn flos_per_layer(m: &ModelPreset, s: usize) -> (f64, f64, f64) {
    let s = s as f64;
    let h = m.hidden as f64;
    let hq = (m.n_q_heads * m.head_dim) as f64;
    let hkv = (m.n_kv_heads * m.head_dim) as f64;
    let f = m.ffn as f64;
    // q,o: 2*s*h*hq each; k,v: 2*s*h*hkv each (GQA-aware)
    let proj = 2.0 * s * h * (2.0 * hq + 2.0 * hkv);
    // scores (2*s^2*hq) + values (2*s^2*hq); Megatron convention: no
    // causal halving.
    let attention = 4.0 * s * s * hq;
    // SwiGLU: gate, up, down matmuls
    let mlp = 6.0 * s * h * f;
    (proj, attention, mlp)
}

/// Total training flos for one iteration over one full sequence `s`.
/// `recompute` adds the checkpointing forward (4x vs 3x forward).
pub fn train_flos(m: &ModelPreset, s: usize, recompute: bool) -> FlosBreakdown {
    let (proj, attention, mlp) = flos_per_layer(m, s);
    let l = m.n_layers as f64;
    let logits = 2.0 * s as f64 * m.hidden as f64 * m.vocab as f64;
    let mult = if recompute { 4.0 } else { 3.0 };
    FlosBreakdown {
        proj: proj * l * mult,
        attention: attention * l * mult,
        mlp: mlp * l * mult,
        logits: logits * mult,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;

    #[test]
    fn llama8b_32k_forward_magnitude() {
        // Hand-computed: ~1.05e15 forward flos at 32K (see DESIGN.md).
        let m = preset("llama3-8b").unwrap();
        let b = train_flos(m, 32_768, true);
        let fwd = b.forward_total() / 4.0;
        assert!((fwd - 1.05e15).abs() / 1.05e15 < 0.05, "{fwd:e}");
    }

    #[test]
    fn attention_dominates_at_multi_million() {
        let m = preset("llama3-8b").unwrap();
        let short = train_flos(m, 8_192, true);
        let long = train_flos(m, 3_700_000, true);
        assert!(short.attention_fraction() < 0.3);
        assert!(long.attention_fraction() > 0.95); // §5.4's observation
    }

    #[test]
    fn recompute_multiplier_is_4_over_3() {
        let m = preset("llama3-8b").unwrap();
        let with = train_flos(m, 65_536, true).forward_total();
        let without = train_flos(m, 65_536, false).forward_total();
        assert!(((with / without) - 4.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn quadratic_attention_scaling() {
        let m = preset("llama3-8b").unwrap();
        let a = train_flos(m, 100_000, true).attention;
        let b = train_flos(m, 200_000, true).attention;
        assert!((b / a - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gqa_reduces_proj_flos() {
        let m = preset("llama3-8b").unwrap(); // 32q/8kv
        let mha = ModelPreset { n_kv_heads: 32, ..m.clone() };
        let (p_gqa, ..) = flos_per_layer(m, 10_000);
        let (p_mha, ..) = flos_per_layer(&mha, 10_000);
        assert!(p_gqa < p_mha);
    }
}
