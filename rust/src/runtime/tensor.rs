//! Host-side tensors: the coordinator's working representation.
//!
//! Everything the coordinator moves between ranks, checkpoints, offloads,
//! shards for ZeRO, or feeds to PJRT is a `HostTensor`. f32 end-to-end on
//! the CPU client (see DESIGN.md substitutions).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn size_bytes(&self) -> usize {
        4
    }

    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype `{other}`"),
        }
    }
}

/// Dense row-major tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn numel(&self) -> usize {
        numel(self.shape())
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    /// Scalar extraction (loss values, token counts).
    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (copy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an XLA literal (copy), recovering shape + dtype.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported element type {other:?}"),
        }
    }

    /// Elementwise accumulate (gradient reduction).
    pub fn add_assign(&mut self, other: &HostTensor) -> Result<()> {
        anyhow::ensure!(self.shape() == other.shape(), "shape mismatch in add");
        let dst = self.as_f32_mut()?;
        let src = other.as_f32()?;
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
        Ok(())
    }

    pub fn scale(&mut self, a: f32) -> Result<()> {
        for d in self.as_f32_mut()? {
            *d *= a;
        }
        Ok(())
    }

    /// L2 norm (gradient clipping / debugging).
    pub fn l2_norm(&self) -> Result<f64> {
        Ok(self
            .as_f32()?
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::f32(vec![3], vec![0.5, 0.5, 0.5]);
        a.add_assign(&b).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[1.5, 2.5, 3.5]);
        assert!(a.add_assign(&HostTensor::zeros(&[4])).is_err());
    }

    #[test]
    fn scalar_round_trip() {
        let s = HostTensor::scalar(2.5);
        assert_eq!(s.scalar_f32().unwrap(), 2.5);
        assert!(HostTensor::zeros(&[2]).scalar_f32().is_err());
    }

    #[test]
    fn literal_round_trip_f32_and_i32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), t);
        let ti = HostTensor::i32(vec![3], vec![7, -100, 2]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), ti);
    }
}
