//! Roofline iteration-time model: compute time at a sequence-dependent
//! kernel efficiency, plus non-overlapped communication and offload terms.
//!
//! Efficiency curve: achieved/peak rises toward a plateau as the sequence
//! grows and attention (large, MXU-friendly matmuls) dominates — the
//! paper's TFLOPS column climbs 231 -> 514 -> 576 -> 590.6 the same way.
//! We use eff(s) = EFF_MAX * s / (s + S_HALF), calibrated on Table 1
//! (EFF_MAX 0.6 ~= 590/989 plateau; S_HALF 50K reproduces the 32K row).

use crate::config::{ClusterConfig, FeatureFlags, ModelPreset, PlanKind};
use crate::coordinator::ring::{ring_bwd_bytes, ring_fwd_bytes};
use crate::coordinator::ulysses::a2a_bytes_per_block;
use crate::perf::flos::{train_flos, train_flos_packed, FlosBreakdown};

pub const EFF_MAX: f64 = 0.60;
pub const S_HALF: f64 = 50_000.0;

/// Kernel efficiency as a function of the effective sequence length the
/// attention matmuls span: the full length for one document, the
/// token-weighted mean segment length for a packed batch.
pub fn efficiency(eff_seq: f64) -> f64 {
    EFF_MAX * eff_seq / (eff_seq + S_HALF)
}

#[derive(Debug, Clone)]
pub struct IterationModel {
    pub model: ModelPreset,
    pub cluster: ClusterConfig,
    pub flags: FeatureFlags,
    /// Which `ParallelPlan` the attention comm term prices.
    pub plan: PlanKind,
}

/// Ring rotation wire time, intra- and inter-node legs priced
/// separately. Within a node the neighbor exchange rides NVLink; once the
/// ring spans nodes, the node-boundary links ride the fabric and — since
/// every hop advances at the pace of its slowest link — they gate the
/// rotation. `exposed()` is therefore the max of the legs, and a hybrid
/// plan (Ulysses intra-node, ring inter-node) would re-price the intra
/// leg on this same struct without touching callers.
#[derive(Debug, Clone, Copy, Default)]
pub struct RingCommCost {
    pub intra_s: f64,
    pub inter_s: f64,
}

impl RingCommCost {
    pub fn exposed(&self) -> f64 {
        self.intra_s.max(self.inter_s)
    }
}

/// Price `per_rank_bytes` of ring neighbor-exchange traffic on the
/// cluster: all-NVLink when the ring fits in one node, both legs when it
/// spans nodes.
pub fn ring_comm_seconds(
    cluster: &ClusterConfig,
    sp: usize,
    per_rank_bytes: f64,
) -> RingCommCost {
    if sp <= 1 {
        return RingCommCost::default();
    }
    let intra_s = per_rank_bytes / cluster.intra_bw_bytes_per_s;
    if sp <= cluster.gpus_per_node {
        RingCommCost { intra_s, inter_s: 0.0 }
    } else {
        RingCommCost { intra_s, inter_s: per_rank_bytes / cluster.inter_bw_bytes_per_s }
    }
}

#[derive(Debug, Clone)]
pub struct PerfResult {
    pub seq: usize,
    pub sp: usize,
    pub iteration_s: f64,
    pub compute_s: f64,
    pub a2a_s: f64,
    pub zero_comm_s: f64,
    pub offload_s: f64,
    /// Per-GPU achieved TFLOPS by the paper's accounting: model flos for
    /// the sequence, divided by SP (each rank computes 1/sp of it) and by
    /// iteration time. Without SP each GPU owns its own sequence (DP).
    pub tflops_per_gpu: f64,
}

/// Model one training iteration at sequence `seq` across `world` GPUs.
pub fn iteration_time(m: &IterationModel, seq: usize, world: usize) -> PerfResult {
    let flos = train_flos(&m.model, seq, m.flags.activation_checkpointing);
    iteration_with_flos(m, seq, world, &flos, seq as f64)
}

/// Packed-batch iteration time: attention flos are Σᵢ Sᵢ² (see
/// `train_flos_packed`), and kernel efficiency is evaluated at the
/// token-weighted mean segment length ΣSᵢ²/ΣSᵢ — the expected segment a
/// random token's attention matmul spans — instead of the full packed
/// length. Everything sequence-linear (a2a volume, offload traffic) uses
/// the total token count, which packing leaves unchanged.
pub fn iteration_time_packed(
    m: &IterationModel,
    seg_lens: &[usize],
    world: usize,
) -> PerfResult {
    let seq: usize = seg_lens.iter().sum();
    assert!(seq > 0, "packed batch has no tokens");
    let flos = train_flos_packed(&m.model, seg_lens, m.flags.activation_checkpointing);
    let eff_seq = seg_lens.iter().map(|&s| (s * s) as f64).sum::<f64>() / seq as f64;
    iteration_with_flos(m, seq, world, &flos, eff_seq)
}

fn iteration_with_flos(
    m: &IterationModel,
    seq: usize,
    world: usize,
    flos: &FlosBreakdown,
    eff_seq: f64,
) -> PerfResult {
    let sp = if !m.flags.ulysses_sp {
        1
    } else if m.plan == PlanKind::Ring {
        // ring has no heads >= sp bound: the whole world participates
        world
    } else {
        m.model.valid_sp_degrees(world).into_iter().max().unwrap_or(1)
    };
    let per_gpu_flos = flos.forward_total() / sp as f64;
    let eff = efficiency(eff_seq);
    let mut compute_s = per_gpu_flos / (eff * m.cluster.peak_flops);

    // weights-offload streaming (single-GPU configs): weights cross PCIe
    // once per forward-ish pass; 4 passes with recompute.
    if m.flags.weights_offload {
        let w_bytes = (2 * m.model.params) as f64;
        compute_s += 4.0 * w_bytes / m.cluster.pcie_bw_bytes_per_s;
    }

    // Attention comm, priced per plan. Ulysses all-to-alls cannot overlap
    // with compute (§3.2: "they have to be really fast"): 2 per attention
    // forward; backward re-runs the forward pair (recompute) + 2
    // transposed = 3x the fwd volume, moving the full activation volume.
    // The ring plan instead rotates only KV blocks — (sp-1)/sp of the KV
    // bytes per rank per direction under the causal-skip schedule, far
    // below the a2a activation volume — priced on the neighbor links
    // (intra- and inter-node legs separately; the slowest leg is exposed).
    let a2a_s = if sp <= 1 {
        0.0
    } else if m.plan == PlanKind::Ring {
        let per_layer = (ring_fwd_bytes(seq, m.model.n_kv_heads, m.model.head_dim, sp, 2)
            + ring_bwd_bytes(seq, m.model.n_kv_heads, m.model.head_dim, sp, 2))
            as f64;
        let per_rank = per_layer * m.model.n_layers as f64 / sp as f64;
        ring_comm_seconds(&m.cluster, sp, per_rank).exposed()
    } else {
        let per_block = a2a_bytes_per_block(
            seq,
            m.model.n_q_heads,
            m.model.n_kv_heads,
            m.model.head_dim,
            sp,
            2,
        ) as f64;
        let vol = per_block * m.model.n_layers as f64 * 3.0 / sp as f64;
        vol / m.cluster.collective_bw(sp)
    };

    // ZeRO-3 param gathers (fwd + bwd regather) + grad reduce-scatter;
    // largely overlappable with compute — 30% exposed.
    let zero_comm_s = if m.flags.zero3 && world > 1 {
        let w_bytes = (2 * m.model.params) as f64;
        let g_bytes = (4 * m.model.params) as f64;
        0.3 * (2.0 * w_bytes + g_bytes) / m.cluster.collective_bw(world)
    } else {
        0.0
    };

    // Checkpoint offload: device->host on forward (overlappable),
    // host->device on backward (the paper notes this one cannot overlap,
    // fn.16) — count the backward direction fully, forward at 20%.
    let offload_s = if m.flags.ckpt_offload {
        let ckpt_bytes = (seq / sp) as f64
            * m.model.hidden as f64
            * 2.0
            * m.model.n_layers as f64;
        (1.0 + 0.2) * ckpt_bytes / m.cluster.pcie_bw_bytes_per_s
    } else {
        0.0
    };

    let iteration_s = compute_s + a2a_s + zero_comm_s + offload_s;
    let tflops_per_gpu = per_gpu_flos / iteration_s / 1e12;
    PerfResult {
        seq,
        sp,
        iteration_s,
        compute_s,
        a2a_s,
        zero_comm_s,
        offload_s,
        tflops_per_gpu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::preset;

    fn model(flags: FeatureFlags, nodes: usize) -> IterationModel {
        IterationModel {
            model: preset("llama3-8b").unwrap().clone(),
            cluster: ClusterConfig::h100(nodes),
            flags,
            plan: PlanKind::Ulysses,
        }
    }

    fn ring_model(flags: FeatureFlags, nodes: usize) -> IterationModel {
        IterationModel { plan: PlanKind::Ring, ..model(flags, nodes) }
    }

    #[test]
    fn table1_row1_baseline_32k() {
        // paper: 0:00:17, 231.6 TFLOPS (8 GPUs, DP, 32K each)
        let r = iteration_time(&model(FeatureFlags::baseline(), 1), 32_768, 8);
        assert!(r.iteration_s > 10.0 && r.iteration_s < 30.0, "{r:?}");
        assert!(r.tflops_per_gpu > 180.0 && r.tflops_per_gpu < 300.0, "{r:?}");
    }

    #[test]
    fn table1_row6_full_alst_3_7m() {
        // paper: 1:47:35 (6455s), 590.6 TFLOPS at 3.7M on 8 GPUs.
        let r = iteration_time(&model(FeatureFlags::alst(), 1), 3_700_000, 8);
        assert_eq!(r.sp, 8);
        let hours = r.iteration_s / 3600.0;
        assert!(hours > 1.4 && hours < 2.4, "{hours}h");
        assert!(r.tflops_per_gpu > 520.0 && r.tflops_per_gpu < 640.0, "{r:?}");
    }

    #[test]
    fn table2_single_gpu_500k() {
        // paper: 0:16:50 (1010s), 548.1 TFLOPS at 500K on 1 GPU.
        let mut f = FeatureFlags::alst();
        f.weights_offload = true;
        let r = iteration_time(&model(f, 1), 500_000, 1);
        let mins = r.iteration_s / 60.0;
        assert!(mins > 12.0 && mins < 24.0, "{mins}min");
        assert!(r.tflops_per_gpu > 430.0 && r.tflops_per_gpu < 620.0, "{r:?}");
    }

    #[test]
    fn tflops_rise_toward_plateau_with_seq() {
        let m = model(FeatureFlags::alst(), 1);
        let a = iteration_time(&m, 100_000, 8).tflops_per_gpu;
        let b = iteration_time(&m, 1_000_000, 8).tflops_per_gpu;
        let c = iteration_time(&m, 3_700_000, 8).tflops_per_gpu;
        assert!(a < b && b < c);
        assert!(c < EFF_MAX * 989.0 + 1.0);
    }

    #[test]
    fn quadratic_slowdown_with_seq() {
        // §5.4: iteration time grows superlinearly (attention is O(s^2)).
        let m = model(FeatureFlags::alst(), 1);
        let t1 = iteration_time(&m, 1_000_000, 8).iteration_s;
        let t2 = iteration_time(&m, 2_000_000, 8).iteration_s;
        assert!(t2 > 3.0 * t1, "{t1} -> {t2}");
    }

    #[test]
    fn packing_short_docs_is_cheaper_than_one_long_doc() {
        // §5.4 corollary: at equal token count, k packed documents cost a
        // fraction of one long document (attention dominates at 2M).
        let m = model(FeatureFlags::alst(), 1);
        let total = 2_000_000usize;
        let one = iteration_time(&m, total, 8);
        let packed = iteration_time_packed(&m, &vec![total / 16; 16], 8);
        assert_eq!(packed.seq, total);
        assert!(
            packed.iteration_s < 0.5 * one.iteration_s,
            "{} vs {}",
            packed.iteration_s,
            one.iteration_s
        );
        // sequence-linear terms are unchanged by packing
        assert_eq!(packed.a2a_s, one.a2a_s);
        assert_eq!(packed.offload_s, one.offload_s);
    }

    #[test]
    fn packed_single_segment_matches_unpacked() {
        let m = model(FeatureFlags::alst(), 1);
        let a = iteration_time(&m, 500_000, 8);
        let b = iteration_time_packed(&m, &[500_000], 8);
        assert!((a.iteration_s - b.iteration_s).abs() < 1e-12);
        assert!((a.tflops_per_gpu - b.tflops_per_gpu).abs() < 1e-9);
    }

    #[test]
    fn a2a_cost_present_only_with_sp() {
        let with = iteration_time(&model(FeatureFlags::alst(), 1), 500_000, 8);
        let without =
            iteration_time(&model(FeatureFlags::baseline(), 1), 500_000, 8);
        assert!(with.a2a_s > 0.0);
        assert_eq!(without.a2a_s, 0.0);
    }

    #[test]
    fn ring_legs_price_intra_vs_inter_separately() {
        let c = ClusterConfig::h100(2);
        let fits = ring_comm_seconds(&c, 8, 1e9);
        assert_eq!(fits.inter_s, 0.0, "one-node ring rides NVLink only");
        assert!(fits.intra_s > 0.0);
        assert_eq!(fits.exposed(), fits.intra_s);
        let spans = ring_comm_seconds(&c, 16, 1e9);
        assert!(spans.inter_s > spans.intra_s, "fabric leg gates the rotation");
        assert_eq!(spans.exposed(), spans.inter_s);
        assert_eq!(ring_comm_seconds(&c, 1, 1e9).exposed(), 0.0);
    }

    #[test]
    fn ring_comm_undercuts_a2a_within_a_node() {
        // Same geometry, same node: ring rotates only KV blocks while the
        // a2a moves the full q+kv+o activation volume.
        let ul = iteration_time(&model(FeatureFlags::alst(), 1), 1_000_000, 8);
        let ring = iteration_time(&ring_model(FeatureFlags::alst(), 1), 1_000_000, 8);
        assert_eq!(ul.sp, 8);
        assert_eq!(ring.sp, 8);
        assert!(ring.a2a_s > 0.0);
        assert!(ring.a2a_s < ul.a2a_s, "{} !< {}", ring.a2a_s, ul.a2a_s);
    }

    #[test]
    fn ring_scales_sp_past_the_head_bound() {
        // llama3-8b caps Ulysses at sp=32; a 64-GPU ring uses all ranks,
        // and the model still prices an iteration (no panics, no silent
        // fallback).
        let ul = iteration_time(&model(FeatureFlags::alst(), 8), 3_200_000, 64);
        assert_eq!(ul.sp, 32);
        let ring = iteration_time(&ring_model(FeatureFlags::alst(), 8), 3_200_000, 64);
        assert_eq!(ring.sp, 64);
        assert!(ring.compute_s < ul.compute_s, "64-way sharding beats 32-way");
    }
}
