//! Packing subsystem benchmarks: FFD packer throughput, adapter shard
//! latency, and the paper-arithmetic payoff table — packing efficiency
//! and modeled step time versus naive one-document-per-sequence padding
//! at the same corpus.
//!
//! Runs fully offline (no artifacts needed): the packer/adapter are pure
//! rust, the step times come from the roofline model.

use alst::config::{preset, ClusterConfig, FeatureFlags, PlanKind};
use alst::packing::{
    pack_ffd, shard_packed, Document, DocumentSource, MixedLengthSource, PackedSequence,
    PackingStats,
};
use alst::perf::{iteration_time, iteration_time_packed, IterationModel};
use alst::util::bench::{fmt_seqlen, quick, Table};

fn corpus(n_docs: usize, min: usize, max: usize, seed: u64) -> Vec<Document> {
    let mut src = MixedLengthSource::new(1000, min, max, seed);
    (0..n_docs).map(|_| src.next_document()).collect()
}

fn main() {
    println!("bench_packing: FFD packer + segment-aware adapter\n");

    // ---- packer throughput ---------------------------------------------
    for (n, cap) in [(1_000usize, 4_096usize), (10_000, 4_096), (10_000, 65_536)] {
        let docs = corpus(n, 16, cap / 2, 1);
        let tokens: usize = docs.iter().map(Document::len).sum();
        let r = quick(
            &format!("pack_ffd {n} docs -> cap {}", fmt_seqlen(cap)),
            || {
                let packs = pack_ffd(docs.clone(), cap).unwrap();
                std::hint::black_box(packs.len());
            },
        );
        let per_sec = tokens as f64 / r.median.as_secs_f64();
        println!("    -> {:.1}M tokens/s packed", per_sec / 1e6);
    }

    // ---- adapter (materialize + shard) ---------------------------------
    let docs = corpus(256, 64, 2_048, 2);
    let packs = pack_ffd(docs, 8_192).unwrap();
    let seqs: Vec<PackedSequence> = packs
        .iter()
        .map(|p| PackedSequence::from_pack(p).unwrap())
        .collect();
    quick("shard_packed sp=8 over 8K packs", || {
        for p in &seqs {
            std::hint::black_box(shard_packed(p, 8).len());
        }
    });

    // ---- packing efficiency + modeled step time vs padding -------------
    let model = preset("llama3-8b").unwrap();
    let im = IterationModel {
        model: model.clone(),
        cluster: ClusterConfig::h100(1),
        flags: FeatureFlags::alst(),
        plan: PlanKind::Ulysses,
    };
    let world = 8usize;
    let capacity = 1_048_576usize; // 1M-token packs
    let mut table = Table::new(
        "packed vs one-doc-per-sequence padding (llama3-8b, 8xH100 model)",
        &[
            "corpus",
            "docs",
            "packs",
            "efficiency",
            "packed step",
            "padded steps",
            "speedup",
        ],
    );
    for (label, min, max) in [
        ("chat-heavy 1K-32K", 1_024usize, 32_768usize),
        ("mixed 4K-256K", 4_096, 262_144),
        ("long-doc 64K-1M", 65_536, 1_048_576),
    ] {
        let docs = corpus(512, min, max, 7);
        let n_docs = docs.len();
        let lens: Vec<usize> = docs.iter().map(Document::len).collect();
        let packs = pack_ffd(docs, capacity).unwrap();
        let stats = PackingStats::from_packs(&packs);

        // packed: each pack is one step over the MATERIALIZED sequence —
        // the padding segment included, since the trainer processes the
        // full capacity-length sequence (linear terms pay for padding
        // too; only attention is per-segment).
        let packed_s: f64 = packs
            .iter()
            .map(|p| {
                let seg = PackedSequence::from_pack(p).unwrap().segment_lengths();
                iteration_time_packed(&im, &seg, world).iteration_s
            })
            .sum();
        // naive padding: one capacity-length step per document, the
        // document alone in the sequence (attention still runs over the
        // padded length — what a no-packer dataloader pays).
        let padded_s = lens.len() as f64 * iteration_time(&im, capacity, world).iteration_s;
        table.row(&[
            label.to_string(),
            n_docs.to_string(),
            packs.len().to_string(),
            format!("{:.1}%", 100.0 * stats.efficiency()),
            format!("{:.0}s", packed_s),
            format!("{:.0}s", padded_s),
            format!("{:.0}x", padded_s / packed_s),
        ]);
    }
    table.print();
    println!(
        "\n(padded = every doc alone in a {}-token sequence; packed = FFD\n \
         bins, attention cost summed per segment)",
        fmt_seqlen(capacity)
    );
}
