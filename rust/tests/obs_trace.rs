//! Tier-1 tests for the observability subsystem (`src/obs/`).
//!
//! Artifact-free sections always run: span-multiset parity between serial
//! and threaded `run_ranks`, exact span/ledger reconciliation for the
//! marshal and collective paths, and a full synthetic traced "step"
//! (relayouts + tape offload + tiled loss sweep + real marshals) whose
//! Chrome export passes the CI validator. The end-to-end PJRT section
//! gates on `artifacts/` like the rest of the integration suite.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use alst::collectives::Group;
use alst::coordinator::dataloader::{MarkovSource, UlyssesDataLoader, IGNORE_INDEX};
use alst::coordinator::pipeline::{run_ranks, Trainer, TrainerOptions};
use alst::coordinator::tape::CheckpointTape;
use alst::coordinator::ulysses::{a2a_head_to_seq_into, a2a_seq_to_head_into};
use alst::memory::{HostPool, MemoryTracker};
use alst::obs::{
    rank_scope, trace_events, validate_trace, AttributionReport, Category, Span, Tracer,
};
use alst::runtime::{Engine, HostTensor, Manifest, ScratchArena};
use alst::tiling::exec::{HostLossHead, TiledLossExec};
use alst::util::rng::Rng;

fn artifacts(config: &str, sp: usize, seq: usize) -> Option<PathBuf> {
    let dir = Manifest::artifact_dir(Path::new("artifacts"), config, sp, seq);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {} missing — run `make artifacts`", dir.display());
        None
    }
}

/// The per-rank traced workload used by the parity test: a couple of
/// hand-opened spans plus a ledgered instant collective, all tagged with
/// the scoped rank by `run_ranks`.
fn traced_rank_run(sp: usize, parallel: bool) -> Vec<Span> {
    let tracer = Arc::new(Tracer::new(true));
    let mut group = Group::new(sp);
    group.set_tracer(tracer.clone());
    let (t, g) = (&tracer, &group);
    run_ranks(sp, parallel, |r| {
        {
            let mut s = t.span(Category::Exec, "stage_a");
            s.set_bytes((r as u64 + 1) * 64);
        }
        g.account_all_to_all((r as u64 + 1) * 8)?;
        {
            let mut s = t.span(Category::Marshal, "upload");
            s.set_bytes(32);
        }
        Ok(())
    })
    .unwrap();
    tracer.drain()
}

/// ISSUE 6 satellite: `parallel_ranks: true` vs `false` must record the
/// same span multiset — names, categories, ranks, byte attributes —
/// timestamps excluded (the same contract the CommStats byte ledger pins
/// in relayout_equiv.rs).
#[test]
fn threaded_and_serial_ranks_record_the_same_span_multiset() {
    let sp = 4;
    let key = |spans: &[Span]| -> Vec<(String, Category, Option<usize>, u64)> {
        let mut v: Vec<_> = spans
            .iter()
            .map(|s| (s.name.clone(), s.cat, s.rank, s.bytes))
            .collect();
        v.sort();
        v
    };
    let serial = traced_rank_run(sp, false);
    let threaded = traced_rank_run(sp, true);
    assert_eq!(serial.len(), 3 * sp);
    assert_eq!(key(&serial), key(&threaded));
    // every span carries its scoped rank, under both executors
    assert!(serial.iter().all(|s| s.rank.is_some()));
    assert!(threaded.iter().all(|s| s.rank.is_some()));
}

/// Marshal spans carry the SAME `Duration` values `EngineStats`
/// accumulates — sums agree bit-for-bit, not within tolerance.
#[test]
fn marshal_spans_reconcile_with_engine_stats_exactly() {
    let tracer = Arc::new(Tracer::new(true));
    let mut engine = Engine::cpu().unwrap();
    engine.set_tracer(tracer.clone());
    for i in 1..=5usize {
        let t = HostTensor::zeros(&[64 * i]);
        engine.to_buffer(&t).unwrap();
    }
    let st = engine.stats();
    let spans = tracer.drain();
    let marshal: Vec<&Span> =
        spans.iter().filter(|s| s.cat == Category::Marshal).collect();
    assert_eq!(marshal.len(), 5);
    let dur: Duration = marshal.iter().map(|s| s.dur()).sum();
    assert_eq!(dur, st.marshal_time);
    let bytes: u64 = marshal.iter().map(|s| s.bytes).sum();
    assert_eq!(bytes, st.bytes_in);
}

/// Relayouts emit one Relayout span per call plus the nested instant
/// collective spans; the collective span bytes sum to the CommStats
/// ledger exactly.
#[test]
fn relayout_and_collective_spans_reconcile_with_comm_ledger() {
    let (sp, ssh, n_q, d) = (4usize, 64usize, 8usize, 16usize);
    let tracer = Arc::new(Tracer::new(true));
    let mut group = Group::new(sp);
    group.set_tracer(tracer.clone());
    let arena = ScratchArena::new();
    let mut rng = Rng::new(3);
    let q: Vec<HostTensor> = (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, n_q, d], rng.normal_vec(ssh * n_q * d, 1.0)))
        .collect();

    let full = a2a_seq_to_head_into(&group, &q, &arena).unwrap();
    let back = a2a_head_to_seq_into(&group, &full, n_q, false, &arena).unwrap();
    arena.recycle_all(full);
    arena.recycle_all(back);

    let st = group.stats();
    let spans = tracer.drain();
    let relayout: Vec<&Span> =
        spans.iter().filter(|s| s.cat == Category::Relayout).collect();
    assert_eq!(relayout.len(), 2);
    assert_eq!(relayout[0].name, "a2a_seq_to_head");
    assert_eq!(relayout[1].name, "a2a_head_to_seq");
    // each relayout span's byte attribute is the volume it ledgered
    let relayout_bytes: u64 = relayout.iter().map(|s| s.bytes).sum();
    assert_eq!(relayout_bytes, st.all_to_all_bytes);
    // the nested instant collective spans sum to the same ledger
    let coll_bytes: u64 = spans
        .iter()
        .filter(|s| s.cat == Category::Collective)
        .map(|s| s.bytes)
        .sum();
    assert_eq!(coll_bytes, st.total_bytes());
}

/// The full artifact-free traced step: relayout cycle, offloading
/// checkpoint tape, real `to_buffer` marshals, and a tiled loss sweep
/// over the host reference head — the same workload the `trace`
/// subcommand falls back to in CI. The export must pass the validator
/// and the attribution report must tie memory peaks to spans.
#[test]
fn synthetic_traced_step_exports_valid_chrome_trace() {
    let (sp, ssh, n_q, d) = (2usize, 128usize, 4usize, 8usize);
    let (hidden, vocab, rows) = (16usize, 32usize, 32usize);
    let tracer = Arc::new(Tracer::new(true));
    let mut engine = Engine::cpu().unwrap();
    engine.set_tracer(tracer.clone());
    let mut group = Group::new(sp);
    group.set_tracer(tracer.clone());
    let mut device = MemoryTracker::new(1 << 40);
    device.set_tracer(tracer.clone());
    let mut host = HostPool::new(1 << 40);
    let arena = ScratchArena::new();
    let mut rng = Rng::new(9);

    let q: Vec<HostTensor> = (0..sp)
        .map(|_| HostTensor::f32(vec![ssh, n_q, d], rng.normal_vec(ssh * n_q * d, 1.0)))
        .collect();
    let head = HostLossHead::new(
        hidden,
        vocab,
        IGNORE_INDEX,
        vec![1.0; hidden],
        rng.normal_vec(hidden * vocab, 0.02),
    )
    .unwrap();
    let h = HostTensor::f32(vec![ssh, hidden], rng.normal_vec(ssh * hidden, 1.0));
    let labels: Vec<i32> = (0..ssh).map(|i| (i % vocab) as i32).collect();

    for step in 0..2u64 {
        let mut step_span = tracer.span(Category::Step, "trace_step");
        step_span.set_step(step + 1);

        let full = a2a_seq_to_head_into(&group, &q, &arena).unwrap();
        let back = a2a_head_to_seq_into(&group, &full, n_q, false, &arena).unwrap();
        arena.recycle_all(full);
        arena.recycle_all(back);

        let mut tape = CheckpointTape::new(1, sp, true).with_tracer(tracer.clone());
        for r in 0..sp {
            tape.store(0, r, HostTensor::zeros(&[ssh, hidden]), &mut device, &mut host)
                .unwrap();
        }
        for r in 0..sp {
            let t = tape.fetch(0, r, &mut device, &mut host).unwrap();
            engine.to_buffer(&t).unwrap();
        }

        for r in 0..sp {
            let _rank = rank_scope(r);
            let drv = TiledLossExec::new(ssh, hidden, vocab, rows, IGNORE_INDEX, &arena)
                .unwrap()
                .with_tracer(tracer.clone());
            let sweep = drv
                .forward(&mut device, &h, &labels, |ht, lt| {
                    let losses = head.per_row_losses(ht.as_f32()?, lt.as_i32()?)?;
                    Ok(HostTensor::f32(vec![losses.len()], losses))
                })
                .unwrap();
            arena.recycle_f32(sweep.per_row_loss);
        }
    }

    let spans = tracer.drain();
    let mem = device.take_events();
    assert!(!mem.is_empty(), "tiled sweep should emit tracker events");
    // every traced category but Exec/Optimizer appears in this workload
    for cat in [
        Category::Step,
        Category::Marshal,
        Category::Relayout,
        Category::Collective,
        Category::Offload,
        Category::Tile,
    ] {
        assert!(
            spans.iter().any(|s| s.cat == cat),
            "no {cat:?} span recorded"
        );
    }

    let doc = trace_events(&spans, &mem);
    validate_trace(&doc).unwrap();

    let rep = AttributionReport::build(&spans, &mem);
    assert_eq!(rep.steps.len(), 2);
    // Tile is a container: it must never enter the per-step leaf sums
    assert!(rep.steps.iter().all(|s| !s.by_cat.contains_key(&Category::Tile)));
    let peak = rep.mem_peak.expect("tracker events imply a peak");
    assert!(peak.bytes > 0);
    assert_ne!(peak.span_name, "(no span)", "peak should name its span");
}

/// End-to-end (needs artifacts): a traced 2-step PJRT run. The emitted
/// trace passes the validator; the attribution report's exec/marshal
/// sums equal `EngineStats` EXACTLY (same Duration values); each step
/// span's duration equals the reported `StepMetrics::step_time`.
#[test]
fn traced_train_run_reconciles_with_ledgers() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let opts = TrainerOptions {
        trace: true,
        parallel_ranks: false,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&dir, opts).unwrap();
    let vocab = trainer.manifest.config.vocab;
    let mut loader = UlyssesDataLoader::new(MarkovSource::new(vocab, 256, 0.05, 1), 2);
    let mut metrics = Vec::new();
    for _ in 0..2 {
        let (ids, _) = loader.next();
        metrics.push(trainer.train_step_accum(&[ids]).unwrap());
    }
    let engine_stats = trainer.engine.stats();
    let spans = trainer.tracer().drain();
    let mem = trainer.device.take_events();

    // Chrome export passes the CI validator.
    let doc = trace_events(&spans, &mem);
    validate_trace(&doc).unwrap();

    let rep = AttributionReport::build(&spans, &mem);
    assert_eq!(rep.steps.len(), 2);

    // Exec/marshal span totals carry the SAME Duration values the engine
    // ledger accumulated — bit-for-bit equality, zero tolerance.
    assert_eq!(rep.total(Category::Exec).dur, engine_stats.exec_time);
    assert_eq!(rep.total(Category::Marshal).dur, engine_stats.marshal_time);
    assert_eq!(rep.total(Category::Exec).spans as u64, engine_stats.executions);

    // Each step span reports the exact StepMetrics duration and step id.
    for (att, m) in rep.steps.iter().zip(&metrics) {
        assert_eq!(att.step, Some(m.step));
        assert_eq!(att.step_time, m.step_time);
        // serial ranks: leaf work is a sub-portion of the wall step
        assert!(att.tracked() <= att.step_time);
        // the a2a relayout volume the step reported appears as span bytes
        let relayout = att.cat(Category::Relayout);
        assert_eq!(relayout.bytes, m.a2a_bytes);
    }
}
