//! End-to-end training driver (EXPERIMENTS.md §E2E): train a ~100M-param
//! Llama-style transformer on synthetic Markov data for a few hundred
//! steps through the complete stack — ZeRO-3 sharding, Ulysses SP=4,
//! pre-shifted-label dataloader, checkpoint offload accounting, AdamW —
//! and log the loss curve.
//!
//!     cargo run --release --example train_e2e -- \
//!         --config e2e-100m --sp 4 --seq 1024 --steps 300 \
//!         --csv results/e2e_100m_loss.csv
//!
//! `--config e2e-25m --seq 512` is the faster variant used while
//! iterating (single CPU core: the 100M config costs ~40-90s/step).

use alst::coordinator::dataloader::{BatchSource, CorpusSource, MarkovSource, UlyssesDataLoader};
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::metrics::RunLog;
use alst::runtime::Manifest;
use alst::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "e2e-100m");
    let sp = args.usize("sp", 4);
    let seq = args.usize("seq", 1024);
    let steps = args.usize("steps", 300);
    let seed = args.usize("seed", 0) as u64;
    let lr = args.f64("lr", 6e-4) as f32;

    let dir = Manifest::artifact_dir(std::path::Path::new("artifacts"), &config, sp, seq);
    let mut opts = TrainerOptions { seed, ..Default::default() };
    opts.adamw.lr = lr;
    // linear warmup + cosine decay (stabilizes the first optimizer steps
    // at batch-size 1; without it gradient norms spike ~100x early on)
    opts.lr_schedule = Some(alst::coordinator::pipeline::LrSchedule {
        peak_lr: lr,
        warmup_steps: args.usize("warmup", 20) as u64,
        total_steps: steps as u64,
        min_lr: lr * 0.1,
    });
    let mut trainer = Trainer::new(&dir, opts)?;
    let vocab = trainer.manifest.config.vocab;
    println!(
        "e2e: {} ({:.1}M params)  sp={} seq={} steps={} lr={}",
        config,
        trainer.manifest.config.params_count as f64 / 1e6,
        sp,
        seq,
        steps,
        lr
    );
    println!("chance loss = ln({vocab}) = {:.3}", (vocab as f32).ln());

    // --data FILE: byte-tokenized tiny corpus (vocab 256 subset); default
    // is the synthetic Markov stream. The corpus path learns much faster
    // per step (each byte transition is visited hundreds of times).
    let source: Box<dyn BatchSource> = if let Some(path) = args.get("data") {
        println!("corpus: {path} (byte-level)");
        Box::new(CorpusSource::from_file(std::path::Path::new(path), seq, seed)?)
    } else {
        Box::new(MarkovSource::new(vocab, seq, 0.05, seed ^ 1))
    };
    let mut loader = UlyssesDataLoader::new(source, sp);
    let mut log = RunLog::default();
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let (ids, _) = loader.next();
        let m = trainer.train_step(&ids)?;
        if step <= 5 || step % 10 == 0 {
            println!(
                "step {:>4}/{}  loss {:.4}  gnorm {:.2}  {:.1}s  (elapsed {:.0}s)",
                step,
                steps,
                m.loss,
                m.grad_norm,
                m.step_time.as_secs_f64(),
                t0.elapsed().as_secs_f64()
            );
        }
        log.push(m);
    }

    println!("\n{}", log.ascii_loss_curve(68, 14));
    let head = log.mean_loss_head(10);
    let tail = log.mean_loss_tail(10);
    println!(
        "mean loss: first 10 steps {head:.4} -> last 10 steps {tail:.4} \
         ({} tokens total, {:.1}s/step)",
        log.total_tokens(),
        log.mean_step_time().as_secs_f64()
    );

    let csv = args.get_or("csv", "results/e2e_loss.csv");
    if let Some(parent) = std::path::Path::new(&csv).parent() {
        std::fs::create_dir_all(parent)?;
    }
    log.write_csv(std::path::Path::new(&csv))?;
    println!("loss curve written to {csv}");

    anyhow::ensure!(tail < head, "loss did not decrease: {head} -> {tail}");
    println!("train_e2e OK");
    Ok(())
}
