//! Step-timeline simulation (Figure 7): replay one fwd+bwd iteration's
//! allocation sequence through the `MemoryTracker`, with and without
//! checkpoint offload, and show that offload turns the per-layer "hill"
//! into a flat line — peak device memory stops depending on layer count.
//!
//! Unlike the static estimator this walks the SAME event order the real
//! pipeline executes (checkpoint store per layer going forward, fetch per
//! layer going backward, transient working buffers per phase).

use crate::config::{FeatureFlags, ModelPreset};
use crate::memory::{HostPool, MemoryTracker};

#[derive(Debug, Clone)]
pub struct TimelineResult {
    /// Device bytes sampled after every alloc/free event.
    pub samples: Vec<u64>,
    pub device_peak: u64,
    pub host_peak: u64,
    /// Peak attributable to checkpoints alone.
    pub ckpt_peak: u64,
}

/// Replay one training iteration's memory events.
pub fn simulate_step(
    m: &ModelPreset,
    seq: usize,
    sp: usize,
    flags: &FeatureFlags,
    device_budget: u64,
    host_budget: u64,
) -> anyhow::Result<TimelineResult> {
    let t_r = seq / sp.max(1);
    let act_b = 2u64; // bf16 activations (simulator units)
    let ckpt_bytes = (t_r * m.hidden) as u64 * act_b;
    // per-layer transient working set (attention + mlp, coarse)
    let work_bytes = {
        let attn = (seq * (m.n_q_heads / sp.max(1)).max(1) * m.head_dim) as u64 * 4 * act_b;
        let mlp_rows = if flags.tiled_mlp { m.hidden.min(t_r) } else { t_r };
        let mlp = (mlp_rows * 2 * m.ffn) as u64 * act_b;
        attn + mlp
    };

    let mut dev = MemoryTracker::new(device_budget);
    let mut host = HostPool::new(host_budget);
    let mut ckpt_peak = 0u64;

    // forward: store one checkpoint per layer, run the layer, free work
    for _li in 0..m.n_layers {
        if flags.ckpt_offload {
            host.alloc(ckpt_bytes)?;
        } else {
            dev.alloc(ckpt_bytes, "ckpt")?;
        }
        ckpt_peak = ckpt_peak.max(dev.tag_bytes("ckpt"));
        dev.alloc(work_bytes, "work")?;
        dev.free(work_bytes, "work");
    }
    // loss head
    let logits_rows = if flags.tiled_loss { 8192.min(t_r) } else { t_r };
    let logits = (logits_rows * m.vocab) as u64 * 4 * 2;
    dev.alloc(logits, "logits")?;
    dev.free(logits, "logits");

    // backward: fetch checkpoints in reverse, recompute + grads
    for _li in (0..m.n_layers).rev() {
        dev.alloc(2 * work_bytes, "work")?; // recompute + gradient buffers
        dev.free(2 * work_bytes, "work");
        if flags.ckpt_offload {
            host.free(ckpt_bytes);
        } else {
            dev.free(ckpt_bytes, "ckpt");
        }
    }

    Ok(TimelineResult {
        samples: dev.timeline.clone(),
        device_peak: dev.peak(),
        host_peak: host.peak(),
        ckpt_peak,
    })
}

/// Derive the async offload engine's per-layer H2D prefetch schedule from
/// the same backward walk `simulate_step` replays. `ok[li]` means layer
/// `li`'s checkpoint may be fetched one phase *early* — while the phase
/// above it is still computing — because the device can hold the extra
/// resident checkpoint on top of that phase's working set:
///
/// - `ok[n_layers-1]`: prefetched during the loss head, which holds
///   `head_bytes` of logits/loss buffers.
/// - `ok[li]` (li < n_layers-1): prefetched during layer `li+1`'s
///   recompute, which holds `2*work_bytes` (recompute + gradient buffers,
///   the same figure `simulate_step` charges) plus layer `li+1`'s own
///   restored checkpoint.
///
/// When a layer's slot is `false` the engine falls back to fetching at
/// the start of that layer's backward phase (the stall the paper says
/// "cannot overlap much" — but only for that layer).
pub fn prefetch_schedule(
    n_layers: usize,
    ckpt_bytes: u64,
    work_bytes: u64,
    head_bytes: u64,
    device_budget: u64,
) -> Vec<bool> {
    let mut ok = vec![false; n_layers];
    if n_layers == 0 {
        return ok;
    }
    // u128 sums: budgets and paper-scale byte counts can legitimately be
    // near u64 limits in the simulator; the comparison must not wrap.
    let budget = device_budget as u128;
    ok[n_layers - 1] = head_bytes as u128 + ckpt_bytes as u128 <= budget;
    let mid_need = 2 * work_bytes as u128 + 2 * ckpt_bytes as u128;
    for slot in ok.iter_mut().take(n_layers - 1) {
        *slot = mid_need <= budget;
    }
    ok
}

/// ASCII sparkline of the timeline (examples/doc output).
pub fn sparkline(samples: &[u64], width: usize) -> String {
    if samples.is_empty() {
        return String::new();
    }
    let max = *samples.iter().max().unwrap() as f64;
    let glyphs = [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let step = (samples.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < samples.len() && out.chars().count() < width {
        let v = samples[i as usize] as f64;
        let idx = if max == 0.0 { 0 } else { ((v / max) * 8.0).round() as usize };
        out.push(glyphs[idx.min(8)]);
        i += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, FeatureFlags, GIB};

    fn run(offload: bool, layers_scale: usize) -> TimelineResult {
        let mut m = preset("llama3-8b").unwrap().clone();
        m.n_layers *= layers_scale;
        let mut f = FeatureFlags::alst();
        f.ckpt_offload = offload;
        simulate_step(&m, 500_000, 8, &f, 1 << 45, 1 << 45).unwrap()
    }

    #[test]
    fn offload_flattens_the_hill() {
        let hill = run(false, 1);
        let flat = run(true, 1);
        // Figure 7: same step, offload removes the checkpoint ramp
        assert!(hill.device_peak > flat.device_peak + GIB);
        assert!(flat.ckpt_peak == 0);
        assert!(flat.host_peak > 0);
    }

    #[test]
    fn peak_independent_of_layer_count_only_with_offload() {
        // the paper's claim: "peak memory no longer depends on how many
        // layers the model has"
        let flat1 = run(true, 1);
        let flat2 = run(true, 2);
        assert_eq!(flat1.device_peak, flat2.device_peak);
        let hill1 = run(false, 1);
        let hill2 = run(false, 2);
        assert!(hill2.device_peak > hill1.device_peak + GIB);
    }

    #[test]
    fn timeline_shape_is_a_hill_without_offload() {
        let hill = run(false, 1);
        let peak_pos = hill
            .samples
            .iter()
            .position(|&v| v == hill.device_peak)
            .unwrap();
        // peak happens somewhere in the middle (end of fwd / start of bwd),
        // and the timeline returns to ~zero
        assert!(peak_pos > hill.samples.len() / 4);
        assert_eq!(*hill.samples.last().unwrap(), 0);
    }

    #[test]
    fn oom_when_device_budget_too_small() {
        let m = preset("llama3-8b").unwrap();
        let err = simulate_step(m, 500_000, 8, &FeatureFlags::baseline(), GIB, 1 << 45);
        assert!(err.is_err());
    }

    #[test]
    fn prefetch_schedule_tracks_device_headroom() {
        // Generous budget: every layer prefetches one phase early.
        assert_eq!(prefetch_schedule(3, 100, 200, 500, 10_000), vec![true; 3]);
        // Budget fits loss head + one checkpoint (500 + 100) but not a
        // mid-layer phase with two resident checkpoints (2*200 + 2*100):
        // only the top layer overlaps its fetch.
        assert_eq!(prefetch_schedule(3, 100, 200, 500, 650), vec![false, false, true]);
        // Too tight for anything: the engine degrades to fetch-on-demand.
        assert_eq!(prefetch_schedule(3, 100, 200, 500, 300), vec![false; 3]);
        // Degenerate shapes.
        assert!(prefetch_schedule(0, 100, 200, 500, 1 << 40).is_empty());
        assert_eq!(prefetch_schedule(1, 100, 0, 0, 99), vec![false]);
        // Near-u64 inputs must not wrap the comparison into `true`.
        assert_eq!(
            prefetch_schedule(2, u64::MAX, u64::MAX, u64::MAX, u64::MAX),
            vec![false, false]
        );
    }

    #[test]
    fn sparkline_renders() {
        let s = sparkline(&[0, 1, 2, 3, 4, 4, 3, 2, 1, 0], 10);
        assert!(!s.is_empty());
        assert!(s.contains('█'));
    }
}
