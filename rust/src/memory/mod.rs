//! Memory substrate: device-memory model, allocation tracker, host offload
//! pool, the paper-formula estimator, and the max-seqlen search.
//!
//! Substitution (DESIGN.md): no H100s exist here; the paper's max-seqlen
//! results are memory-capacity arithmetic, so the simulator implements the
//! paper's own byte formulas (§2.1, §3.1, §3.3) — driven by the *same*
//! coordinator decisions (tile plans, shard shapes, offload) the real
//! pipeline uses — and is validated against every worked number in the
//! paper's text.

mod estimator;
mod hostpool;
mod search;
mod timeline;
mod tracker;

pub use estimator::{
    packed_mask_bytes, position_ids_bytes, ActivationBreakdown, Calibration, Estimator,
    MemoryBreakdown,
};
pub use hostpool::HostPool;
pub use search::{max_seqlen_search, SearchOutcome};
pub use timeline::{prefetch_schedule, simulate_step, sparkline, TimelineResult};
pub use tracker::{DeviceModel, MemoryTracker, OomError};
