"""Rust <-> Pallas packed-layout contract (mirror of
`rust/tests/packed_integration.rs`).

The rust packing subsystem (`rust/src/packing/`) materializes seg_ids /
position_ids / cu_seqlens for packed batches; the Pallas kernel
`packed_attn.py` consumes the same convention. These fixtures are
hard-coded IDENTICALLY on both sides: if either implementation drifts,
one of the two suites fails. No hypothesis dependency — this file must
run in minimal environments.
"""
from __future__ import annotations

import numpy as np

from compile.kernels import packed_attn


def cu_seqlens_from(lengths):
    """cu_seqlens as the rust side defines it: [0, cumsum(lengths)...]."""
    return np.concatenate([[0], np.cumsum(lengths)]).astype(np.int32)


class TestRustLayoutContract:
    def test_fixture_3_2_4(self):
        seg, pos = packed_attn.make_packed_segments([3, 2, 4])
        np.testing.assert_array_equal(seg, [0, 0, 0, 1, 1, 2, 2, 2, 2])
        np.testing.assert_array_equal(pos, [0, 1, 2, 0, 1, 0, 1, 2, 3])
        np.testing.assert_array_equal(cu_seqlens_from([3, 2, 4]), [0, 3, 5, 9])

    def test_fixture_2_3(self):
        seg, pos = packed_attn.make_packed_segments([2, 3])
        np.testing.assert_array_equal(seg, [0, 0, 1, 1, 1])
        np.testing.assert_array_equal(pos, [0, 1, 0, 1, 2])
        np.testing.assert_array_equal(cu_seqlens_from([2, 3]), [0, 2, 5])

    def test_seg_ids_and_cu_seqlens_describe_the_same_mask(self):
        """The kernel's block rule `causal & (seg_q == seg_k)` must equal
        the mask implied by cu_seqlens windows — the rust coordinator
        ships cu_seqlens, the kernel consumes seg_ids."""
        lengths = [3, 2, 4, 1]
        seg, _ = packed_attn.make_packed_segments(lengths)
        seg = np.asarray(seg)
        cu = cu_seqlens_from(lengths)
        s = int(seg.shape[0])
        causal = np.tril(np.ones((s, s), bool))
        kernel_mask = causal & (seg[:, None] == seg[None, :])
        window_mask = np.zeros((s, s), bool)
        for a, b in zip(cu[:-1], cu[1:]):
            window_mask[a:b, a:b] = causal[a:b, a:b]
        np.testing.assert_array_equal(kernel_mask, window_mask)

    def test_positions_reset_exactly_at_cu_boundaries(self):
        lengths = [5, 1, 7, 2]
        _, pos = packed_attn.make_packed_segments(lengths)
        pos = np.asarray(pos)
        cu = cu_seqlens_from(lengths)
        for a, b in zip(cu[:-1], cu[1:]):
            np.testing.assert_array_equal(pos[a:b], np.arange(b - a))

    def test_shift_labels_packed_semantics(self):
        """Mirror of `packing::shift_labels_packed`: shift within each
        segment, IGNORE_INDEX (-100) at every segment's last token."""
        IGNORE = -100
        lengths = [3, 2, 4]
        ids = np.concatenate(
            [100 * (i + 1) + np.arange(n) for i, n in enumerate(lengths)]
        )
        cu = cu_seqlens_from(lengths)
        labels = np.full_like(ids, IGNORE)
        for a, b in zip(cu[:-1], cu[1:]):
            labels[a : b - 1] = ids[a + 1 : b]
        np.testing.assert_array_equal(
            labels, [101, 102, IGNORE, 201, IGNORE, 301, 302, 303, IGNORE]
        )
        # the naive whole-sequence shift leaks one target per boundary
        naive = np.concatenate([ids[1:], [IGNORE]])
        leaks = np.nonzero(naive != labels)[0]
        np.testing.assert_array_equal(leaks, cu[1:-1] - 1)
