//! Micro-bench harness (criterion is unavailable offline): warmup, timed
//! iterations, mean/median/p95 reporting, and table emission for the paper
//! reproduction benches.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12?}  median {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95, self.min
        )
    }
}

/// Run `f` repeatedly: `warmup` throwaway iterations, then at least
/// `min_iters` and at least `min_time` of measurement.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize,
                         min_time: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        median: samples[samples.len() / 2],
        p95: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
        min: samples[0],
    };
    println!("{}", res.report());
    res
}

/// Quick default: 2 warmups, >=10 iters, >=300ms.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 2, 10, Duration::from_millis(300), f)
}

/// Fixed-width table printer for the paper-table benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Also emit machine-readable CSV (used by EXPERIMENTS.md collection).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.header.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// Human formatting for sequence lengths (paper style: 32K, 3.7M, 15M).
pub fn fmt_seqlen(s: usize) -> String {
    if s >= 1_000_000 {
        let m = s as f64 / 1_000_000.0;
        if m >= 10.0 { format!("{:.0}M", m) } else { format!("{:.1}M", m) }
    } else if s >= 1_000 {
        format!("{}K", s / 1_000)
    } else {
        s.to_string()
    }
}

pub fn fmt_duration_hms(d: Duration) -> String {
    let total = d.as_secs();
    format!("{}:{:02}:{:02}", total / 3600, (total % 3600) / 60, total % 60)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, Duration::from_millis(1), || {});
        assert!(r.iters >= 5);
        assert!(r.min <= r.median && r.median <= r.p95);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,bb\n1,2\n");
    }

    #[test]
    fn seqlen_formatting_matches_paper_style() {
        assert_eq!(fmt_seqlen(32_768), "32K");
        assert_eq!(fmt_seqlen(500_000), "500K");
        assert_eq!(fmt_seqlen(3_700_000), "3.7M");
        assert_eq!(fmt_seqlen(15_000_000), "15M");
    }

    #[test]
    fn hms_formatting() {
        assert_eq!(fmt_duration_hms(Duration::from_secs(17)), "0:00:17");
        assert_eq!(fmt_duration_hms(Duration::from_secs(6455)), "1:47:35");
    }
}
