"""AOT exporter: lower every Ulysses stage (fwd + vjp) to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the rust `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

For a (config, seq, sp) triple this writes:

    artifacts/<config>-sp<sp>-seq<seq>/
        embed_fwd.hlo.txt ... loss_bwd.hlo.txt   (10 stage programs)
        manifest.json                            (shapes + param layout)

The manifest is the single source of truth the rust coordinator reads: it
drives the flat-parameter layout for ZeRO sharding, artifact input order,
and the Ulysses head-shard shapes.

Usage:  python -m compile.aot --config tiny --seq 256 --sp 2 --out ../artifacts
        python -m compile.aot --all --out ../artifacts      (default build set)
"""
from __future__ import annotations

import argparse
import functools
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


GIB = 1 << 30


def loss_tile_rows(cfg: M.ModelConfig, ssh: int, chunk_bytes: int) -> int:
    """Rows per loss-head tile: the §3.1 ~`chunk_bytes` fp32 logits slice
    (`chunk_bytes / 4 / vocab` rows), clamped to the shard and rounded
    down to a multiple of the CE kernel's `tile_s` so the kernel grid
    divides evenly (BOTH kernel paths — pallas and the lax.scan ref —
    assert `s % tile_s == 0`; rows below tile_s rely on the stage-side
    `min(tile_s, rows)` clamp). Mirrors
    `rust/src/tiling::logits_chunk_rows`; the rust driver re-derives the
    value from the exported stage shapes, so this is the single source.

    Rejects a chunk budget smaller than one fp32 vocab row — the
    degenerate config `tiling::plan_logits_checked` documents: 1-row
    tiles whose bytes silently EXCEED the budget.
    """
    if chunk_bytes // 4 < cfg.vocab:
        raise ValueError(
            f"--chunk-bytes {chunk_bytes} holds no fp32 vocab row "
            f"({4 * cfg.vocab} B): 1-row tiles would exceed the budget"
        )
    rows = max(1, (chunk_bytes // 4) // cfg.vocab)
    rows = min(rows, ssh)
    if rows > cfg.tile_s:
        rows -= rows % cfg.tile_s
    return rows


def mlp_tile_rows(cfg: M.ModelConfig, ssh: int) -> int:
    """Rows per MLP tile under the §3.1.1 auto-shard rule
    `ceil(ssh / ceil(ssh / hidden))` (mirrors `rust/src/tiling`), with
    the same alignment rule as `loss_tile_rows`: rounded down to a
    multiple of the MLP kernel's `tile_s` (both kernel paths assert
    divisibility; rows below `tile_s` are handled by the stage-side
    clamp in `post_attn_fwd`). The rust driver pads the resulting
    ragged tail, so a smaller tile only means one more tile."""
    shards = max(1, -(-ssh // cfg.hidden))
    rows = -(-ssh // shards)
    if rows > cfg.tile_s:
        rows -= rows % cfg.tile_s
    return rows


def stage_specs(cfg: M.ModelConfig, seq: int, sp: int,
                loss_chunk_bytes: int = GIB) -> dict:
    """Input ShapeDtypeStructs for every stage, keyed by stage name.

    Shapes follow the Ulysses layouts: `ssh = seq/sp` outside attention,
    full `seq` with per-rank head shards inside it.
    """
    assert seq % sp == 0, (seq, sp)
    ssh = seq // sp
    h, v, d = cfg.hidden, cfg.vocab, cfg.head_dim
    nq, nkv = cfg.n_q_heads, cfg.n_kv_heads
    q_sh, kv_sh = cfg.head_shard(sp)
    hq, hkv = nq * d, nkv * d

    emb = [("embed", spec((v, h))), ("ids", spec((ssh,), I32))]
    pre = [
        ("ln1", spec((h,))), ("wq", spec((h, hq))),
        ("wk", spec((h, hkv))), ("wv", spec((h, hkv))),
        ("h", spec((ssh, h))), ("pos", spec((ssh,), I32)),
    ]
    attn = [
        ("q", spec((seq, q_sh, d))),
        ("k", spec((seq, kv_sh, d))),
        ("v", spec((seq, kv_sh, d))),
    ]
    post = [
        ("wo", spec((hq, h))), ("ln2", spec((h,))),
        ("wg", spec((h, cfg.ffn))), ("wu", spec((h, cfg.ffn))),
        ("wd", spec((cfg.ffn, h))),
        ("h_in", spec((ssh, h))), ("attn", spec((ssh, nq, d))),
    ]
    loss = [
        ("lnf", spec((h,))), ("unembed", spec((h, v))),
        ("h", spec((ssh, h))), ("labels", spec((ssh,), I32)),
    ]
    # Row-tiled stage shapes (§3.1 executed): OPTIONAL stages — rust
    # manifests without them still load, and the coordinator falls back
    # to the monolithic loss/post_attn path.
    t_loss = loss_tile_rows(cfg, ssh, loss_chunk_bytes)
    t_mlp = mlp_tile_rows(cfg, ssh)
    loss_tile = [
        ("lnf", spec((h,))), ("unembed", spec((h, v))),
        ("h", spec((t_loss, h))), ("labels", spec((t_loss,), I32)),
    ]
    mlp_tile = [
        ("wo", spec((hq, h))), ("ln2", spec((h,))),
        ("wg", spec((h, cfg.ffn))), ("wu", spec((h, cfg.ffn))),
        ("wd", spec((cfg.ffn, h))),
        ("h_in", spec((t_mlp, h))), ("attn", spec((t_mlp, nq, d))),
    ]
    return {
        "embed_fwd": (M.embed_fwd, emb),
        "embed_bwd": (M.embed_bwd, emb + [("d_h", spec((ssh, h)))]),
        "pre_attn_fwd": (M.pre_attn_fwd, pre),
        "pre_attn_bwd": (M.pre_attn_bwd, pre + [
            ("d_q", spec((ssh, nq, d))),
            ("d_k", spec((ssh, nkv, d))),
            ("d_v", spec((ssh, nkv, d))),
        ]),
        "attn_fwd": (M.attn_core_fwd, attn),
        "attn_bwd": (M.attn_core_bwd, attn + [("d_o", spec((seq, q_sh, d)))]),
        "post_attn_fwd": (M.post_attn_fwd, post),
        "post_attn_bwd": (M.post_attn_bwd, post + [("d_out", spec((ssh, h)))]),
        "loss_fwd": (M.loss_fwd, loss),
        "loss_bwd": (M.loss_bwd, loss + [("ct_sum", spec(()))]),
        # Tiled execution stages: loss_bwd_tile IS loss_bwd at tile
        # shapes; mlp_{fwd,bwd}_tile ARE post_attn_{fwd,bwd} at tile
        # shapes (the whole post-attention block is row-wise).
        "loss_fwd_tile": (M.loss_fwd_tile, loss_tile),
        "loss_bwd_tile": (M.loss_bwd, loss_tile + [("ct_sum", spec(()))]),
        "mlp_fwd_tile": (M.post_attn_fwd, mlp_tile),
        "mlp_bwd_tile": (M.post_attn_bwd,
                         mlp_tile + [("d_out", spec((t_mlp, h)))]),
    }


# Parameter groups in flat-buffer order. Rust's ZeRO sharding flattens
# [embed group][layer 0]...[layer L-1][final group] in exactly this order.
def param_layout(cfg: M.ModelConfig) -> dict:
    h, v, d = cfg.hidden, cfg.vocab, cfg.head_dim
    hq, hkv = cfg.n_q_heads * d, cfg.n_kv_heads * d
    return {
        "embed": [("embed", [v, h], "normal")],
        "layer": [
            ("ln1", [h], "ones"),
            ("wq", [h, hq], "normal"),
            ("wk", [h, hkv], "normal"),
            ("wv", [h, hkv], "normal"),
            ("wo", [hq, h], "normal"),
            ("ln2", [h], "ones"),
            ("wg", [h, cfg.ffn], "normal"),
            ("wu", [h, cfg.ffn], "normal"),
            ("wd", [cfg.ffn, h], "zeros"),
        ],
        "final": [("lnf", [h], "ones"), ("unembed", [h, v], "normal")],
    }


def _shape_entry(name, s):
    return {
        "name": name,
        "shape": list(s.shape),
        "dtype": "i32" if s.dtype == jnp.int32 else "f32",
    }


def export(cfg: M.ModelConfig, seq: int, sp: int, out_root: pathlib.Path,
           kernels: str | None = None,
           loss_chunk_bytes: int = GIB) -> pathlib.Path:
    if kernels and kernels != cfg.kernels:
        # Kernel-swap variant gets its own artifact dir (attention-agnostic
        # property: rust loads either with zero coordinator changes).
        cfg = dataclasses_replace(cfg, name=f"{cfg.name}-{kernels}",
                                  kernels=kernels)
    out = out_root / f"{cfg.name}-sp{sp}-seq{seq}"
    out.mkdir(parents=True, exist_ok=True)
    specs = stage_specs(cfg, seq, sp, loss_chunk_bytes=loss_chunk_bytes)
    stages = {}
    for name, (fn, inputs) in specs.items():
        bound = functools.partial(fn, cfg)
        # keep_unused: the stage signature IS the rust-side contract; jit
        # must not DCE arguments whose values a particular VJP ignores
        # (e.g. embed_bwd only uses the embedding's shape).
        lowered = jax.jit(bound, keep_unused=True).lower(*[s for _, s in inputs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        out_avals = jax.eval_shape(bound, *[s for _, s in inputs])
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        stages[name] = {
            "file": fname,
            "inputs": [_shape_entry(n, s) for n, s in inputs],
            "outputs": [_shape_entry(f"out{i}", s)
                        for i, s in enumerate(out_avals)],
        }
        print(f"  {name}: {len(text)} chars")
    q_sh, kv_sh = cfg.head_shard(sp)
    manifest = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "hidden": cfg.hidden,
            "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads, "ffn": cfg.ffn,
            "head_dim": cfg.head_dim, "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps, "kernels": cfg.kernels,
            "params_count": cfg.params_count(),
        },
        "seq": seq, "sp": sp, "seq_shard": seq // sp,
        "q_heads_shard": q_sh, "kv_heads_shard": kv_sh,
        "ignore_index": M.IGNORE_INDEX,
        # Informational echo: rust re-derives tile rows from the tile
        # stages' input shapes (single source of truth is the stage IO).
        "tile_rows": {
            "loss": loss_tile_rows(cfg, seq // sp, loss_chunk_bytes),
            "mlp": mlp_tile_rows(cfg, seq // sp),
        },
        "stages": stages,
        "param_layout": {
            g: [{"name": n, "shape": sh, "init": init} for n, sh, init in tensors]
            for g, tensors in param_layout(cfg).items()
        },
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return out


def dataclasses_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


# The default build set: everything the examples, tests and benches load.
# Fifth field: loss-head tile chunk bytes. The paper's 1 GiB chunk would
# mean one tile at toy vocab sizes, so the tiny builds shrink it (64 KiB
# = 32 rows at vocab 512) to exercise multi-tile sweeps end to end.
DEFAULT_BUILDS = [
    ("tiny", 256, 1, None, 64 * 1024),
    ("tiny", 256, 2, None, 64 * 1024),
    ("tiny", 256, 4, None, 64 * 1024),  # exercises kv replication (kv=2 < sp=4)
    ("tiny", 256, 2, "ref", 64 * 1024),  # kernel-swap path (attention-agnostic)
    ("e2e-25m", 512, 1, None, GIB),
    ("e2e-25m", 512, 4, None, GIB),
    ("e2e-100m", 512, 4, None, GIB),  # single-core-friendly e2e driver default
    ("e2e-100m", 1024, 4, None, GIB),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", choices=sorted(M.CONFIGS), default=None)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--sp", type=int, default=1)
    ap.add_argument("--kernels", choices=["pallas", "ref"], default=None)
    ap.add_argument("--chunk-bytes", type=int, default=GIB,
                    help="loss-head tile chunk size (§3.1; fp32 bytes)")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--all", action="store_true",
                    help="build the default artifact set")
    args = ap.parse_args()
    out_root = pathlib.Path(args.out)
    if args.all or args.config is None:
        builds = DEFAULT_BUILDS
    else:
        builds = [(args.config, args.seq, args.sp, args.kernels,
                   args.chunk_bytes)]
    for name, seq, sp, kern, chunk in builds:
        cfg = M.CONFIGS[name]
        tag = f"{name}-sp{sp}-seq{seq}" + (f" [{kern}]" if kern else "")
        print(f"export {tag}")
        export(cfg, seq, sp, out_root, kernels=kern, loss_chunk_bytes=chunk)
    print("done")


if __name__ == "__main__":
    main()
