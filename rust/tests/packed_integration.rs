//! Packing subsystem integration tests.
//!
//! The segment-id / position-id / cu_seqlens layout is a CONTRACT between
//! the rust coordinator and the Pallas packed-attention kernel
//! (`python/compile/kernels/packed_attn.py`). The fixtures here are the
//! exact outputs of `make_packed_segments` on the same length lists —
//! `python/tests/test_packing_contract.py::TestRustLayoutContract`
//! asserts the mirror-image fixtures on the python side (and runs
//! without hypothesis, so it survives minimal environments), so a
//! convention drift on either side fails one suite or the other.
//!
//! The PJRT end-to-end packed test gates on `make artifacts` like the
//! rest of the integration suite.

use std::path::{Path, PathBuf};

use alst::config::preset;
use alst::coordinator::dataloader::IGNORE_INDEX;
use alst::coordinator::pipeline::{Trainer, TrainerOptions};
use alst::packing::{
    pack_ffd, shard_packed, Document, MixedLengthSource, PackedDataLoader, PackedSequence,
};
use alst::perf::{packed_attention_ratio, train_flos, train_flos_packed};
use alst::runtime::Manifest;

fn docs_with_lengths(lens: &[usize]) -> Vec<Document> {
    lens.iter()
        .enumerate()
        .map(|(i, &n)| Document::new(i as u64, (0..n as i32).map(|t| 1000 * (i as i32 + 1) + t).collect()))
        .collect()
}

#[test]
fn layout_contract_matches_make_packed_segments() {
    // python: make_packed_segments([3, 2, 4]) ==
    //   seg [0 0 0 1 1 2 2 2 2], pos [0 1 2 0 1 0 1 2 3]
    let p = PackedSequence::from_documents(&docs_with_lengths(&[3, 2, 4])).unwrap();
    assert_eq!(p.seg_ids, vec![0, 0, 0, 1, 1, 2, 2, 2, 2]);
    assert_eq!(p.positions, vec![0, 1, 2, 0, 1, 0, 1, 2, 3]);
    assert_eq!(p.cu_seqlens, vec![0, 3, 5, 9]);

    // python: make_packed_segments([2, 3]) == seg [0 0 1 1 1], pos [0 1 0 1 2]
    let p2 = PackedSequence::from_documents(&docs_with_lengths(&[2, 3])).unwrap();
    assert_eq!(p2.seg_ids, vec![0, 0, 1, 1, 1]);
    assert_eq!(p2.positions, vec![0, 1, 0, 1, 2]);
    assert_eq!(p2.cu_seqlens, vec![0, 2, 5]);
}

#[test]
fn segment_mask_semantics_match_pallas_block_rule() {
    // packed_attn.py masks with `causal & (seg_q == seg_k)`. Reconstruct
    // that mask from the rust layout and check it equals the mask implied
    // by cu_seqlens windows — i.e. both sides describe the same
    // attention pattern.
    let p = PackedSequence::from_documents(&docs_with_lengths(&[3, 2, 4])).unwrap();
    let s = p.len();
    for q in 0..s {
        for k in 0..s {
            let pallas_rule = q >= k && p.seg_ids[q] == p.seg_ids[k];
            let cu_rule = (0..p.n_segments()).any(|seg| {
                let r = p.segment_range(seg);
                r.contains(&q) && r.contains(&k) && q >= k
            });
            assert_eq!(pallas_rule, cu_rule, "mask mismatch at ({q},{k})");
        }
    }
}

#[test]
fn packed_labels_and_shards_never_leak_targets() {
    // end-to-end over the adapter: for every rank of every pack, any
    // non-masked label is the next token of the SAME document.
    let src = MixedLengthSource::new(500, 3, 48, 11);
    let mut dl = PackedDataLoader::new(src, 128, 4, 24).unwrap();
    for _ in 0..6 {
        let (p, shards) = dl.next().unwrap();
        let labels = p.labels();
        for (i, &l) in labels.iter().enumerate() {
            if l != IGNORE_INDEX {
                assert_eq!(p.seg_ids[i], p.seg_ids[i + 1]);
            }
        }
        let recat = alst::packing::gather_shards(&shards);
        assert_eq!(recat.labels, labels, "sharding changed labels");
    }
}

#[test]
fn acceptance_packed_flos_is_one_kth_at_equal_tokens() {
    // ISSUE acceptance: FlosBreakdown for a packed batch of k equal
    // segments reports attention flos ~= 1/k of the unpacked
    // single-document figure at the same total token count.
    let m = preset("llama3-8b").unwrap();
    let total = 524_288usize;
    let single = train_flos(m, total, true).attention;
    for k in [4usize, 16] {
        let packed = train_flos_packed(m, &vec![total / k; k], true).attention;
        let ratio = packed / single;
        assert!((ratio - 1.0 / k as f64).abs() < 1e-9, "k={k}: {ratio}");
        assert!((packed_attention_ratio(&vec![total / k; k]) - 1.0 / k as f64).abs() < 1e-12);
    }
}

#[test]
fn ffd_beats_one_doc_per_sequence_on_mixed_corpora() {
    // the whole point of the subsystem: a mixed-length corpus needs far
    // fewer capacity-length sequences packed than padded one-per-doc.
    let mut src = MixedLengthSource::new(100, 8, 512, 5);
    let docs: Vec<Document> = (0..200)
        .map(|_| alst::packing::DocumentSource::next_document(&mut src))
        .collect();
    let n_docs = docs.len();
    let packs = pack_ffd(docs, 512).unwrap();
    assert!(
        packs.len() * 3 < n_docs,
        "packing should need <1/3 the sequences: {} vs {n_docs}",
        packs.len()
    );
    let stats = alst::packing::PackingStats::from_packs(&packs);
    assert!(stats.efficiency() > 0.8, "{:?}", stats);
}

// ---------------------------------------------------------------------------
// PJRT end-to-end (requires `make artifacts`; skips gracefully)
// ---------------------------------------------------------------------------

fn artifacts(config: &str, sp: usize, seq: usize) -> Option<PathBuf> {
    let dir = Manifest::artifact_dir(Path::new("artifacts"), config, sp, seq);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {} missing — run `make artifacts`", dir.display());
        None
    }
}

#[test]
fn packed_step_trains_and_reports_per_document_loss() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let mut t =
        Trainer::new(&dir, TrainerOptions { seed: 13, ..Default::default() }).unwrap();
    let vocab = t.manifest.config.vocab;
    let src = MixedLengthSource::new(vocab, 16, 200, 9);
    let mut dl = PackedDataLoader::new(src, 256, 2, 12).unwrap();
    let p = dl.next_sequence().unwrap();
    let m = t.train_step_packed(&p).unwrap();
    assert!(m.metrics.loss.is_finite() && m.metrics.loss > 0.0);
    assert_eq!(m.metrics.tokens, 256);
    assert_eq!(m.doc_losses.len(), p.n_docs());
    assert_eq!(m.real_tokens + m.padding_tokens, 256);
    // target-weighted per-doc losses recombine into the aggregate loss
    let (mut num, mut den) = (0f64, 0f64);
    for d in &m.doc_losses {
        let w = d.tokens.saturating_sub(1) as f64;
        num += d.loss as f64 * w;
        den += w;
    }
    let recombined = (num / den) as f32;
    assert!(
        (recombined - m.metrics.loss).abs() < 1e-4,
        "per-doc losses {recombined} != aggregate {}",
        m.metrics.loss
    );
}

#[test]
fn tiled_loss_single_pass_per_doc_and_execution_counts() {
    // ISSUE acceptance: with tiled_loss on, per-document losses come
    // from ONE tiled sweep — the engine's loss-stage execution count is
    // sp x n_tiles per pass, NOT n_tiles + n_docs — and training
    // matches the monolithic path to fp tolerance.
    use alst::runtime::Engine;
    use alst::tiling::exec::LOSS_HEAD_TAG;
    use alst::tiling::plan_logits_rows;

    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let man = Manifest::load(&dir).unwrap();
    if !man.has_tiled_loss() {
        eprintln!("SKIP: artifact predates tile stages — re-run `make artifacts`");
        return;
    }
    let mut t_untiled =
        Trainer::new(&dir, TrainerOptions { seed: 13, ..Default::default() }).unwrap();
    let mut t_tiled = Trainer::new(
        &dir,
        TrainerOptions { seed: 13, tiled_loss: true, ..Default::default() },
    )
    .unwrap();
    let src = MixedLengthSource::new(512, 16, 200, 9);
    let mut dl = PackedDataLoader::new(src, 256, 2, 12).unwrap();
    let p = dl.next_sequence().unwrap();

    let mu = t_untiled.train_step_packed(&p).unwrap();
    t_tiled.engine.reset_stats();
    let mt = t_tiled.train_step_packed(&p).unwrap();

    assert!(
        (mu.metrics.loss - mt.metrics.loss).abs() < 1e-4,
        "tiled loss {} != monolithic {}",
        mt.metrics.loss,
        mu.metrics.loss
    );
    assert_eq!(mu.doc_losses.len(), mt.doc_losses.len());
    for (a, b) in mu.doc_losses.iter().zip(&mt.doc_losses) {
        assert_eq!(a.doc_id, b.doc_id);
        assert_eq!(a.tokens, b.tokens);
        assert!(
            (a.loss - b.loss).abs() < 1e-4,
            "doc {}: tiled {} != rerun {}",
            a.doc_id,
            b.loss,
            a.loss
        );
    }

    // execution-count contract (one fwd + one bwd sweep, nothing per doc)
    let sp = t_tiled.sp();
    let ssh = 256 / sp;
    let rows = man.loss_tile_rows().unwrap();
    let n_tiles = ssh.div_ceil(rows.min(ssh));
    let fwd_key = Engine::stage_key(&t_tiled.manifest, "loss_fwd_tile");
    let bwd_key = Engine::stage_key(&t_tiled.manifest, "loss_bwd_tile");
    let mono_key = Engine::stage_key(&t_tiled.manifest, "loss_fwd");
    assert_eq!(
        t_tiled.engine.executions_for(&fwd_key),
        (sp * n_tiles) as u64,
        "per-doc losses must not re-run the loss head"
    );
    assert_eq!(t_tiled.engine.executions_for(&bwd_key), (sp * n_tiles) as u64);
    assert_eq!(t_tiled.engine.executions_for(&mono_key), 0);
    assert!(p.n_docs() > 1, "fixture should actually pack documents");

    // measured loss-head peak: tiled == the plan's tile bytes, and far
    // below the monolithic path's per-step peak
    let vocab = t_tiled.manifest.config.vocab;
    let plan = plan_logits_rows(ssh, vocab, rows);
    assert_eq!(t_tiled.device.tag_peak(LOSS_HEAD_TAG), plan.tile_bytes);
    assert!(
        t_untiled.device.tag_peak(LOSS_HEAD_TAG)
            >= sp as u64 * plan.untiled_bytes,
        "untiled path must charge the full-shard logits copies"
    );
}

#[test]
fn packed_shards_feed_pipeline_shapes() {
    let Some(dir) = artifacts("tiny", 2, 256) else { return };
    let t = Trainer::new(&dir, TrainerOptions::default()).unwrap();
    let pack = pack_ffd(docs_with_lengths(&[100, 90, 50]), 256).unwrap();
    assert_eq!(pack.len(), 1);
    let p = PackedSequence::from_pack(&pack[0]).unwrap();
    let shards = shard_packed(&p, t.sp());
    assert_eq!(shards.len(), 2);
    for s in &shards {
        assert_eq!(s.batch.ids.len(), 128);
        assert_eq!(s.batch.positions.len(), 128);
        assert_eq!(s.batch.labels.len(), 128);
        assert_eq!(s.seg_ids.len(), 128);
    }
}
